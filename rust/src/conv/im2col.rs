//! im2col: lower CONV to GEMM (paper §3.1).
//!
//! A CONV layer `filters[F, C, KH, KW]` over input `x[C, H, W]` becomes
//! `W_gemm[F, C*KH*KW] · X_col[C*KH*KW, OH*OW]`. GRIM's twist (§4.5): when
//! BCR pruning kills an entire GEMM weight column in all blocks, the
//! corresponding input row need not be materialized — `im2col_skip`.

use crate::tensor::Tensor;

/// Static geometry of one convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// GEMM dims: `[out_c, in_c*kh*kw] x [in_c*kh*kw, out_h*out_w]`.
    pub fn gemm_k(&self) -> usize {
        self.in_c * self.kh * self.kw
    }

    pub fn gemm_n(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// MACs for the dense convolution.
    pub fn macs(&self) -> usize {
        self.out_c * self.gemm_k() * self.gemm_n()
    }
}

/// Reshape CONV weights `[F, C, KH, KW]` into the GEMM matrix
/// `[F, C*KH*KW]` (row-major, so this is a pure reshape).
pub fn weights_to_gemm(w: &Tensor) -> Tensor {
    let (f, c, kh, kw) = w.shape().as_nchw();
    w.clone().reshape(&[f, c * kh * kw])
}

/// Full im2col: `x[C,H,W]` → `[C*KH*KW, OH*OW]`.
pub fn im2col(x: &Tensor, g: &ConvGeom) -> Tensor {
    let dims = x.shape().dims();
    assert_eq!(dims, &[g.in_c, g.in_h, g.in_w], "input shape mismatch");
    let k = g.gemm_k();
    let n = g.gemm_n();
    let mut out = Tensor::zeros(&[k, n]);
    fill_rows(x.data(), g, out.data_mut(), None);
    out
}

/// im2col with row skipping: rows of `X_col` whose GEMM weight column is
/// fully pruned (`dead_cols[row] == true`) are left as zeros and never
/// gathered. Returns the same shape as [`im2col`] so downstream GEMM is
/// unchanged — the saving is the skipped memory traffic.
pub fn im2col_skip(x: &Tensor, g: &ConvGeom, dead_cols: &[bool]) -> Tensor {
    assert_eq!(dead_cols.len(), g.gemm_k());
    let mut out = Tensor::zeros(&[g.gemm_k(), g.gemm_n()]);
    fill_rows(x.data(), g, out.data_mut(), Some(dead_cols));
    out
}

/// Arena variant of [`im2col`]/[`im2col_skip`]: gathers into `out`
/// (length `gemm_k * gemm_n`), zeroing it first so padding and skipped
/// rows read as zeros even in a reused workspace slice.
pub fn im2col_into(xd: &[f32], g: &ConvGeom, dead: Option<&[bool]>, out: &mut [f32]) {
    assert_eq!(xd.len(), g.in_c * g.in_h * g.in_w, "input length mismatch");
    assert_eq!(out.len(), g.gemm_k() * g.gemm_n(), "column buffer length mismatch");
    if let Some(d) = dead {
        assert_eq!(d.len(), g.gemm_k());
    }
    out.fill(0.0);
    fill_rows(xd, g, out, dead);
}

fn fill_rows(xd: &[f32], g: &ConvGeom, out: &mut [f32], dead: Option<&[bool]>) {
    let (oh, ow) = (g.out_h(), g.out_w());
    let n = oh * ow;
    let (h, w) = (g.in_h, g.in_w);
    for c in 0..g.in_c {
        for ki in 0..g.kh {
            for kj in 0..g.kw {
                let row = (c * g.kh + ki) * g.kw + kj;
                if dead.map(|d| d[row]).unwrap_or(false) {
                    continue;
                }
                let orow = &mut out[row * n..(row + 1) * n];
                for oi in 0..oh {
                    let ii = (oi * g.stride + ki) as isize - g.pad as isize;
                    if ii < 0 || ii >= h as isize {
                        continue; // padding row: stays zero
                    }
                    let xbase = (c * h + ii as usize) * w;
                    for oj in 0..ow {
                        let jj = (oj * g.stride + kj) as isize - g.pad as isize;
                        if jj < 0 || jj >= w as isize {
                            continue;
                        }
                        orow[oi * ow + oj] = xd[xbase + jj as usize];
                    }
                }
            }
        }
    }
}

/// Which GEMM-weight columns are completely dead (zero in every row)?
/// Used to drive [`im2col_skip`].
pub fn dead_columns(w_gemm: &Tensor) -> Vec<bool> {
    let (rows, cols) = w_gemm.shape().as_matrix();
    let mut dead = vec![true; cols];
    for r in 0..rows {
        for c in 0..cols {
            if dead[c] && w_gemm.at2(r, c) != 0.0 {
                dead[c] = false;
            }
        }
    }
    dead
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::conv2d_direct;
    use crate::gemm::naive_gemm;
    use crate::util::Rng;

    fn geom() -> ConvGeom {
        ConvGeom { in_c: 3, in_h: 8, in_w: 8, out_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 }
    }

    #[test]
    fn geometry() {
        let g = geom();
        assert_eq!((g.out_h(), g.out_w()), (8, 8));
        assert_eq!(g.gemm_k(), 27);
        assert_eq!(g.gemm_n(), 64);
    }

    #[test]
    fn im2col_gemm_equals_direct_conv() {
        let g = geom();
        let mut rng = Rng::new(1);
        let w = Tensor::rand_uniform(&[g.out_c, g.in_c, g.kh, g.kw], 1.0, &mut rng);
        let x = Tensor::rand_uniform(&[g.in_c, g.in_h, g.in_w], 1.0, &mut rng);
        let direct = conv2d_direct(&x, &w, g.stride, g.pad);
        let cols = im2col(&x, &g);
        let wg = weights_to_gemm(&w);
        let out = naive_gemm(&wg, &cols).reshape(&[g.out_c, g.out_h(), g.out_w()]);
        assert!(out.allclose(&direct, 1e-4, 1e-4));
    }

    #[test]
    fn strided_no_pad() {
        let g = ConvGeom { in_c: 2, in_h: 9, in_w: 9, out_c: 3, kh: 3, kw: 3, stride: 2, pad: 0 };
        let mut rng = Rng::new(2);
        let w = Tensor::rand_uniform(&[g.out_c, g.in_c, g.kh, g.kw], 1.0, &mut rng);
        let x = Tensor::rand_uniform(&[g.in_c, g.in_h, g.in_w], 1.0, &mut rng);
        let direct = conv2d_direct(&x, &w, g.stride, g.pad);
        let out = naive_gemm(&weights_to_gemm(&w), &im2col(&x, &g))
            .reshape(&[g.out_c, g.out_h(), g.out_w()]);
        assert!(out.allclose(&direct, 1e-4, 1e-4));
    }

    #[test]
    fn skip_matches_full_when_weights_zeroed() {
        let g = geom();
        let mut rng = Rng::new(3);
        let mut w = Tensor::rand_uniform(&[g.out_c, g.in_c, g.kh, g.kw], 1.0, &mut rng);
        // kill GEMM columns 5..10 in every filter
        {
            let f = g.out_c;
            let k = g.gemm_k();
            let wd = w.data_mut();
            for r in 0..f {
                for c in 5..10 {
                    wd[r * k + c] = 0.0;
                }
            }
        }
        let wg = weights_to_gemm(&w);
        let dead = dead_columns(&wg);
        assert!(dead[5..10].iter().all(|d| *d));
        let x = Tensor::rand_uniform(&[g.in_c, g.in_h, g.in_w], 1.0, &mut rng);
        let full = naive_gemm(&wg, &im2col(&x, &g));
        let skip = naive_gemm(&wg, &im2col_skip(&x, &g, &dead));
        assert!(full.allclose(&skip, 1e-5, 1e-5));
    }

    #[test]
    fn one_by_one_kernel() {
        let g = ConvGeom { in_c: 4, in_h: 6, in_w: 6, out_c: 2, kh: 1, kw: 1, stride: 1, pad: 0 };
        let mut rng = Rng::new(4);
        let w = Tensor::rand_uniform(&[2, 4, 1, 1], 1.0, &mut rng);
        let x = Tensor::rand_uniform(&[4, 6, 6], 1.0, &mut rng);
        let direct = conv2d_direct(&x, &w, 1, 0);
        let out = naive_gemm(&weights_to_gemm(&w), &im2col(&x, &g)).reshape(&[2, 6, 6]);
        assert!(out.allclose(&direct, 1e-4, 1e-4));
    }

    #[test]
    fn large_kernel_11x11() {
        // §6.3 large-kernel validation path
        let g = ConvGeom { in_c: 2, in_h: 16, in_w: 16, out_c: 2, kh: 11, kw: 11, stride: 1, pad: 5 };
        let mut rng = Rng::new(5);
        let w = Tensor::rand_uniform(&[2, 2, 11, 11], 0.2, &mut rng);
        let x = Tensor::rand_uniform(&[2, 16, 16], 1.0, &mut rng);
        let direct = conv2d_direct(&x, &w, 1, 5);
        let out = naive_gemm(&weights_to_gemm(&w), &im2col(&x, &g)).reshape(&[2, 16, 16]);
        assert!(out.allclose(&direct, 1e-3, 1e-3));
    }
}
