//! Winograd F(2×2, 3×3) convolution — applied to the *dense* baselines, as
//! the paper does ("we apply Winograd optimization for all dense runs",
//! §6.1). 2.25× multiplication reduction for 3×3 stride-1 convolutions.
//!
//! Transforms (Lavin & Gray 2016):
//!   Y = Aᵀ [ (G g Gᵀ) ⊙ (Bᵀ d B) ] A
//! with the standard 4×4/4×3/2×4 matrices for m=2, r=3.

use crate::tensor::Tensor;

const BT: [[f32; 4]; 4] =
    [[1.0, 0.0, -1.0, 0.0], [0.0, 1.0, 1.0, 0.0], [0.0, -1.0, 1.0, 0.0], [0.0, 1.0, 0.0, -1.0]];
const G: [[f32; 3]; 4] =
    [[1.0, 0.0, 0.0], [0.5, 0.5, 0.5], [0.5, -0.5, 0.5], [0.0, 0.0, 1.0]];
const AT: [[f32; 4]; 2] = [[1.0, 1.0, 1.0, 0.0], [0.0, 1.0, -1.0, -1.0]];

/// Transform one 3×3 kernel: `U = G g Gᵀ` (4×4).
fn transform_kernel(g: &[f32]) -> [f32; 16] {
    // tmp = G (4x3) * g (3x3) = 4x3
    let mut tmp = [0.0f32; 12];
    for i in 0..4 {
        for j in 0..3 {
            let mut s = 0.0;
            for k in 0..3 {
                s += G[i][k] * g[k * 3 + j];
            }
            tmp[i * 3 + j] = s;
        }
    }
    // U = tmp (4x3) * Gᵀ (3x4)
    let mut u = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut s = 0.0;
            for k in 0..3 {
                s += tmp[i * 3 + k] * G[j][k];
            }
            u[i * 4 + j] = s;
        }
    }
    u
}

/// Transform one 4×4 input tile: `V = Bᵀ d B`.
fn transform_input(d: &[f32; 16]) -> [f32; 16] {
    let mut tmp = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut s = 0.0;
            for k in 0..4 {
                s += BT[i][k] * d[k * 4 + j];
            }
            tmp[i * 4 + j] = s;
        }
    }
    let mut v = [0.0f32; 16];
    for i in 0..4 {
        for j in 0..4 {
            let mut s = 0.0;
            for k in 0..4 {
                s += tmp[i * 4 + k] * BT[j][k];
            }
            v[i * 4 + j] = s;
        }
    }
    v
}

/// Output transform: `Y = Aᵀ M A` (2×2 from 4×4).
fn transform_output(m: &[f32; 16]) -> [f32; 4] {
    let mut tmp = [0.0f32; 8]; // 2x4
    for i in 0..2 {
        for j in 0..4 {
            let mut s = 0.0;
            for k in 0..4 {
                s += AT[i][k] * m[k * 4 + j];
            }
            tmp[i * 4 + j] = s;
        }
    }
    let mut y = [0.0f32; 4];
    for i in 0..2 {
        for j in 0..2 {
            let mut s = 0.0;
            for k in 0..4 {
                s += tmp[i * 4 + k] * AT[j][k];
            }
            y[i * 2 + j] = s;
        }
    }
    y
}

/// Pre-transform every 3×3 kernel of `w[F,C,3,3]`: `U = G g Gᵀ`,
/// returned flattened as `[F*C*16]`. Weight-only, so the compiler runs
/// this once at plan time and carries the result on the kernel.
pub fn transform_kernels(w: &Tensor) -> Vec<f32> {
    let (f, c, kh, kw) = w.shape().as_nchw();
    assert_eq!((kh, kw), (3, 3), "winograd F(2,3) requires 3x3 kernels");
    let wdat = w.data();
    let mut u = vec![0.0f32; f * c * 16];
    for i in 0..f * c {
        u[i * 16..(i + 1) * 16].copy_from_slice(&transform_kernel(&wdat[i * 9..i * 9 + 9]));
    }
    u
}

/// Arena variant of Winograd F(2×2,3×3): input/output are flat slices,
/// kernel transforms come pre-computed from [`transform_kernels`], and
/// `vbuf` (≥ `16*C` floats) holds the per-tile input transforms — a
/// planned workspace slice on the serving path, so the kernel performs
/// no heap allocation.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_winograd_into(
    xd: &[f32],
    c: usize,
    h: usize,
    wd: usize,
    u: &[f32],
    f: usize,
    pad: usize,
    out: &mut [f32],
    vbuf: &mut [f32],
) {
    assert_eq!(xd.len(), c * h * wd, "input length mismatch");
    assert_eq!(u.len(), f * c * 16, "kernel transform length mismatch");
    let oh = h + 2 * pad - 2;
    let ow = wd + 2 * pad - 2;
    assert_eq!(out.len(), f * oh * ow, "output length mismatch");
    assert!(vbuf.len() >= 16 * c, "vbuf scratch too small");
    let tiles_i = oh.div_ceil(2);
    let tiles_j = ow.div_ceil(2);
    let mut dtile = [0.0f32; 16];
    for ti in 0..tiles_i {
        for tj in 0..tiles_j {
            let i0 = (ti * 2) as isize - pad as isize;
            let j0 = (tj * 2) as isize - pad as isize;
            // V for all channels of one tile — transformed ONCE per
            // (tile, channel) and reused by every filter (this is where
            // Winograd's 2.25x lives).
            for ci in 0..c {
                for a in 0..4 {
                    for b in 0..4 {
                        let ii = i0 + a as isize;
                        let jj = j0 + b as isize;
                        dtile[a * 4 + b] =
                            if ii < 0 || jj < 0 || ii >= h as isize || jj >= wd as isize {
                                0.0
                            } else {
                                xd[(ci * h + ii as usize) * wd + jj as usize]
                            };
                    }
                }
                vbuf[ci * 16..ci * 16 + 16].copy_from_slice(&transform_input(&dtile));
            }
            for fo in 0..f {
                let mut macc = [0.0f32; 16];
                for ci in 0..c {
                    let uk = &u[(fo * c + ci) * 16..(fo * c + ci) * 16 + 16];
                    let v = &vbuf[ci * 16..ci * 16 + 16];
                    for t in 0..16 {
                        macc[t] += uk[t] * v[t];
                    }
                }
                let y = transform_output(&macc);
                for a in 0..2 {
                    for b in 0..2 {
                        let oi = ti * 2 + a;
                        let oj = tj * 2 + b;
                        if oi < oh && oj < ow {
                            out[(fo * oh + oi) * ow + oj] = y[a * 2 + b];
                        }
                    }
                }
            }
        }
    }
}

/// Winograd F(2×2,3×3) convolution, stride 1, arbitrary padding.
/// `x[C,H,W] * w[F,C,3,3] -> [F,OH,OW]`. Allocating wrapper over
/// [`conv2d_winograd_into`] (the reference/baseline path).
pub fn conv2d_winograd(x: &Tensor, w: &Tensor, pad: usize) -> Tensor {
    let d = x.shape().dims();
    let (c, h, wd) = (d[0], d[1], d[2]);
    let (f, c2, _, _) = w.shape().as_nchw();
    assert_eq!(c, c2);
    let u = transform_kernels(w);
    let oh = h + 2 * pad - 2;
    let ow = wd + 2 * pad - 2;
    let mut out = Tensor::zeros(&[f, oh, ow]);
    let mut vbuf = vec![0.0f32; 16 * c];
    conv2d_winograd_into(x.data(), c, h, wd, &u, f, pad, out.data_mut(), &mut vbuf);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct::conv2d_direct;
    use crate::util::Rng;

    #[test]
    fn matches_direct_various_shapes() {
        let mut rng = Rng::new(1);
        for (c, h, wdim, f, pad) in [(1, 4, 4, 1, 0), (3, 8, 8, 4, 1), (2, 7, 9, 3, 1), (4, 6, 6, 2, 0)] {
            let x = Tensor::rand_uniform(&[c, h, wdim], 1.0, &mut rng);
            let w = Tensor::rand_uniform(&[f, c, 3, 3], 1.0, &mut rng);
            let expect = conv2d_direct(&x, &w, 1, pad);
            let got = conv2d_winograd(&x, &w, pad);
            assert!(
                got.allclose(&expect, 1e-3, 1e-3),
                "c={c} h={h} w={wdim} f={f} pad={pad} maxdiff={}",
                got.max_abs_diff(&expect)
            );
        }
    }

    #[test]
    fn kernel_transform_identity_check() {
        // delta kernel: conv = shifted copy; winograd must agree
        let mut g = [0.0f32; 9];
        g[4] = 1.0;
        let x = Tensor::from_vec(&[1, 4, 4], (0..16).map(|v| v as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 3, 3], g.to_vec());
        let got = conv2d_winograd(&x, &w, 1);
        assert!(got.allclose(&x, 1e-4, 1e-4));
    }
}
