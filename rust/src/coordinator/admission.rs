//! Registry-aware admission control: a request for a model that is not
//! resident — but whose `.grimc` artifact exists in the registry's
//! artifact directory — is **parked** in a bounded pending set while the
//! artifact is loaded on a background thread, then re-enqueued, instead
//! of failing with [`ServeError::ModelNotResident`].
//!
//! Invariants (all maintained under the one `parked` lock):
//!
//! * A model with parked requests always has a loader in flight: parking
//!   and the loader-liveness check happen under the lock, and a loader
//!   drains its model's parked list in the same critical section in
//!   which it retires itself — a request parked after that drain finds
//!   no loader registered and spawns a fresh one (which finds the model
//!   resident and turns into a cheap re-enqueue).
//! * Parked requests are bounded by `pending_cap` across all models;
//!   overflow is rejected back to the dispatcher, which fails those
//!   requests with the classic typed error.
//! * A request re-enqueued after a background load carries
//!   `requeued = true`; if it misses again (the model was evicted in
//!   between) it fails immediately rather than looping park → load →
//!   evict forever.
//! * Every request answered with an error here (failed load, closed
//!   queue on re-enqueue, shutdown leftovers) advances the server-wide
//!   and per-model failed counters, exactly like dispatcher-lane
//!   failures — `completed + failed = total responses` holds on the
//!   admission path too.

use super::queue::{InferRequest, InferResponse, RequestQueue, ServeError};
use super::server::PendingMap;
use crate::obs::{Counter, Registry};
use crate::serving::ModelRegistry;
use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Requests waiting out a background load, plus loader liveness.
struct Parked {
    by_model: HashMap<String, Vec<InferRequest>>,
    /// Total parked requests across models (bounded by the cap).
    total: usize,
    /// Models with a loader thread in flight.
    loading: HashSet<String>,
}

/// The admission controller shared by every dispatcher lane.
pub(crate) struct Admission {
    registry: Arc<ModelRegistry>,
    queue: Arc<RequestQueue>,
    pending_resp: Arc<PendingMap>,
    parked: Mutex<Parked>,
    cap: usize,
    /// `grim_background_loads_total{result="ok"|"failed"}`.
    loads_ok: Arc<Counter>,
    loads_failed: Arc<Counter>,
    /// The server's metric registry — [`Self::fail`] charges the
    /// per-model `grim_requests_failed_total` series through it.
    metrics: Arc<Registry>,
    /// The server-wide failed-request count (shared with the lanes).
    failed: Arc<AtomicU64>,
    loaders: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Admission {
    pub fn new(
        registry: Arc<ModelRegistry>,
        queue: Arc<RequestQueue>,
        pending_resp: Arc<PendingMap>,
        cap: usize,
        loads_ok: Arc<Counter>,
        loads_failed: Arc<Counter>,
        metrics: Arc<Registry>,
        failed: Arc<AtomicU64>,
    ) -> Arc<Admission> {
        Arc::new(Admission {
            registry,
            queue,
            pending_resp,
            parked: Mutex::new(Parked {
                by_model: HashMap::new(),
                total: 0,
                loading: HashSet::new(),
            }),
            cap,
            loads_ok,
            loads_failed,
            metrics,
            failed,
            loaders: Mutex::new(Vec::new()),
        })
    }

    /// Try to park `reqs` (all targeting non-resident `model`) for a
    /// background artifact load. Returns the requests that could NOT be
    /// admitted — no artifact on disk, pending set full, or the request
    /// already went around once (`requeued`) — which the caller must
    /// fail with the typed error. An empty return means every request
    /// was parked and will be answered later.
    pub fn try_admit(self: &Arc<Self>, model: &str, reqs: Vec<InferRequest>) -> Vec<InferRequest> {
        let Some(path) = self.registry.artifact_path_for(model) else {
            return reqs;
        };
        let mut rejected = Vec::new();
        let spawn_loader = {
            let mut g = self.parked.lock().unwrap();
            for req in reqs {
                if req.requeued || g.total >= self.cap {
                    rejected.push(req);
                } else {
                    g.total += 1;
                    g.by_model.entry(model.to_string()).or_default().push(req);
                }
            }
            let has_parked = g.by_model.get(model).is_some_and(|v| !v.is_empty());
            has_parked && g.loading.insert(model.to_string())
        };
        if spawn_loader {
            let this = Arc::clone(self);
            let name = model.to_string();
            let handle = std::thread::Builder::new()
                .name(format!("grim-load-{model}"))
                .spawn(move || this.run_load(&name, &path))
                .expect("spawn background loader");
            self.loaders.lock().unwrap().push(handle);
        }
        rejected
    }

    /// Loader thread body: load the artifact (unless the model raced
    /// back in through another path), then drain this model's parked
    /// requests — re-enqueue on success, fail them on error.
    fn run_load(&self, model: &str, path: &Path) {
        // Off the request path: dispatcher lanes keep executing resident
        // models' batches while this thread pays the artifact I/O.
        let result = if self.registry.get(model).is_some() {
            Ok(())
        } else {
            self.registry.load_file(model.to_string(), path).map(|_| ())
        };
        // Retire the loader and take the parked list in ONE critical
        // section — see the module invariants.
        let reqs = {
            let mut g = self.parked.lock().unwrap();
            g.loading.remove(model);
            let reqs = g.by_model.remove(model).unwrap_or_default();
            g.total -= reqs.len();
            reqs
        };
        match result {
            Ok(()) => {
                self.loads_ok.inc();
                for mut req in reqs {
                    req.requeued = true;
                    // Re-enqueued requests keep their original `enqueued`
                    // stamp, so their latency honestly includes the park.
                    if let Err(req) = self.queue.push(req) {
                        // Queue closed (shutdown): answer directly.
                        self.fail(&req, model);
                    }
                }
            }
            Err(e) => {
                self.loads_failed.inc();
                eprintln!("background load of '{model}' from {} failed: {e}", path.display());
                for req in reqs {
                    self.fail(&req, model);
                }
            }
        }
    }

    /// Answer `req` with the typed not-resident error and account it as
    /// failed, server-wide and per-model, mirroring the dispatcher
    /// lanes' failure accounting.
    fn fail(&self, req: &InferRequest, model: &str) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.metrics.counter("grim_requests_failed_total", &[("model", model)]).inc();
        super::server::respond_error(
            &self.pending_resp,
            req,
            ServeError::ModelNotResident { model: model.to_string() },
        );
    }

    /// Currently parked requests (tests / stats).
    pub fn parked_total(&self) -> usize {
        self.parked.lock().unwrap().total
    }

    /// Shutdown: join loader threads (their queue pushes fail once the
    /// queue is closed and turn into direct error responses), then fail
    /// anything still parked. Idempotent.
    pub fn shutdown(&self) {
        let handles: Vec<_> = self.loaders.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        let leftovers: Vec<(String, Vec<InferRequest>)> = {
            let mut g = self.parked.lock().unwrap();
            g.total = 0;
            g.by_model.drain().collect()
        };
        for (model, reqs) in leftovers {
            for req in reqs {
                self.fail(&req, &model);
            }
        }
    }
}

/// Placeholder output for error responses.
pub(crate) fn error_output() -> Tensor {
    Tensor::zeros(&[1])
}

/// Build the error [`InferResponse`] for `req` (shared by the dispatcher
/// lanes and the admission controller).
pub(crate) fn error_response(req: &InferRequest, error: ServeError) -> InferResponse {
    InferResponse {
        id: req.id,
        output: error_output(),
        queue_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
        batch_ms: 0.0,
        exec_ms: 0.0,
        error: Some(error),
    }
}
