//! Minimal std-only HTTP/JSON serving front end: an accept-loop thread
//! plus one short-lived handler thread per connection (no async runtime
//! — the vendored dependency set has no tokio/hyper, and the coordinator
//! already is the concurrency layer: handlers block on the same
//! [`Server`] submit/recv path every in-process client uses, so HTTP
//! adds an ingress, not a second scheduler). Handler threads are
//! bounded by a [`MAX_HANDLERS`]-permit semaphore — while all permits
//! are taken the accept loop stops pulling connections (they queue in
//! the OS backlog), so a connection flood cannot grow OS threads
//! without bound — and infer handlers wait at most [`INFER_TIMEOUT`]
//! for the coordinator's response (504 after that), so a stalled model
//! load cannot pin handlers forever.
//!
//! Endpoints:
//!
//! * `GET /healthz` — liveness, `{"status":"ok"}`.
//! * `GET /metrics` — the full Prometheus text surface
//!   ([`Server::render_prometheus`]).
//! * `GET /stats` — JSON snapshot of [`Server::stats`].
//! * `POST /v1/infer` — run one request. Body (all fields optional):
//!   `{"model": "name", "input": [floats] | "random", "shape": [dims],
//!   "deadline_ms": N}`. Omitted/`"random"` input synthesizes a uniform
//!   random tensor of the target model's input shape (`"shape"`
//!   overrides), so a smoke test needs no float payload. Typed serve
//!   errors map to status codes: deadline → 504, not-resident/no-default
//!   → 404, execution → 400.

use super::queue::ServeError;
use super::server::Server;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::Rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Largest accepted request body (a [3,32,32] CIFAR input is ~40 KB of
/// JSON floats; 8 MiB leaves headroom without letting one socket OOM
/// the process).
const MAX_BODY: usize = 8 << 20;
/// Largest accepted header block.
const MAX_HEAD: usize = 64 << 10;
/// Maximum concurrently running connection-handler threads.
const MAX_HANDLERS: usize = 64;
/// Longest an infer handler waits for the coordinator's response before
/// answering 504 (generous: it exists to unpin handlers from a stalled
/// background model load, not to race healthy requests).
const INFER_TIMEOUT: Duration = Duration::from_secs(120);

/// Minimal counting semaphore (std has none) bounding handler threads.
struct Permits {
    free: Mutex<usize>,
    cv: Condvar,
}

/// One taken permit; returned on drop (so a panicking or failed-to-spawn
/// handler can never leak capacity).
struct Permit(Arc<Permits>);

impl Permits {
    fn new(n: usize) -> Arc<Permits> {
        Arc::new(Permits { free: Mutex::new(n), cv: Condvar::new() })
    }

    /// Take a permit, polling `stop` so shutdown cannot hang behind
    /// stalled handlers; `None` once stopping.
    fn acquire(self: &Arc<Self>, stop: &AtomicBool) -> Option<Permit> {
        let mut free = self.free.lock().unwrap();
        loop {
            if stop.load(Ordering::Relaxed) {
                return None;
            }
            if *free > 0 {
                *free -= 1;
                return Some(Permit(Arc::clone(self)));
            }
            let (g, _) = self.cv.wait_timeout(free, Duration::from_millis(50)).unwrap();
            free = g;
        }
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        *self.0.free.lock().unwrap() += 1;
        self.0.cv.notify_one();
    }
}

/// A running HTTP ingress bound to one [`Server`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    handled: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`; port 0 picks a free port)
    /// and start accepting connections against `server`.
    pub fn start(server: Arc<Server>, addr: &str) -> anyhow::Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("http bind {addr} failed: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let handled = Arc::new(AtomicU64::new(0));
        let accept = {
            let stop = Arc::clone(&stop);
            let handled = Arc::clone(&handled);
            std::thread::Builder::new()
                .name("grim-http".into())
                .spawn(move || {
                    let permits = Permits::new(MAX_HANDLERS);
                    for conn in listener.incoming() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        // Bound handler concurrency: block until a
                        // permit frees up (further connections queue in
                        // the OS accept backlog meanwhile); a stop
                        // request while saturated drops this connection
                        // and exits.
                        let Some(permit) = permits.acquire(&stop) else { break };
                        let server = Arc::clone(&server);
                        let handled = Arc::clone(&handled);
                        // Handlers are detached: each serves exactly one
                        // request (Connection: close) with read and
                        // response timeouts, so they cannot outlive
                        // shutdown by much.
                        let _ = std::thread::Builder::new()
                            .name("grim-http-conn".into())
                            .spawn(move || {
                                let _permit = permit;
                                handle_connection(&server, stream);
                                handled.fetch_add(1, Ordering::Relaxed);
                            });
                    }
                })
                .expect("spawn http accept loop")
        };
        Ok(HttpServer { addr: local, stop, accept: Some(accept), handled })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections served so far.
    pub fn handled(&self) -> u64 {
        self.handled.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept loop. In-flight handlers
    /// finish their one request on their own threads.
    pub fn shutdown(mut self) {
        self.stop_accepting();
    }

    fn stop_accepting(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the blocking accept() so it observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_accepting();
    }
}

fn handle_connection(server: &Server, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let (status, content_type, body) = match read_request(&mut stream) {
        Ok(req) => route(server, &req),
        Err(e) => (400, "application/json", err_json(&e)),
    };
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

struct Request {
    method: String,
    path: String,
    body: String,
}

/// Read one HTTP/1.1 request: header block to CRLFCRLF, then exactly
/// `Content-Length` body bytes.
fn read_request(stream: &mut TcpStream) -> Result<Request, String> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = find_head_end(&buf) {
            break p;
        }
        if buf.len() > MAX_HEAD {
            return Err("header block too large".into());
        }
        let n = stream.read(&mut chunk).map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request".into());
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.lines();
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().map_err(|_| "bad content-length")?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(format!("body too large ({content_length} bytes)"));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(|e| format!("read body: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-body".into());
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let body = String::from_utf8(body).map_err(|_| "body is not utf-8")?;
    Ok(Request { method, path, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn route(server: &Server, req: &Request) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let mut o = Json::obj();
            o.set("status", Json::Str("ok".into()));
            (200, "application/json", o.to_string())
        }
        ("GET", "/metrics") => (200, "text/plain; version=0.0.4", server.render_prometheus()),
        ("GET", "/stats") => (200, "application/json", stats_json(server)),
        ("POST", "/v1/infer") => handle_infer(server, &req.body),
        ("GET" | "POST", _) => (404, "application/json", err_json("no such endpoint")),
        _ => (405, "application/json", err_json("method not allowed")),
    }
}

fn stats_json(server: &Server) -> String {
    let st = server.stats();
    let mut o = Json::obj();
    o.set("completed", Json::Num(st.completed as f64));
    o.set("failed", Json::Num(st.failed as f64));
    o.set("expired", Json::Num(st.expired as f64));
    o.set("batches", Json::Num(st.batches as f64));
    o.set("dispatch_lanes", Json::Num(st.dispatch_lanes as f64));
    o.set("inflight_batches", Json::Num(server.inflight_batches() as f64));
    o.set("throughput_rps", Json::Num(st.throughput_rps));
    o.set("latency_p50_ms", Json::Num(st.latency_ms.p50));
    o.set("latency_p99_ms", Json::Num(st.latency_ms.p99));
    let mut models = Json::obj();
    for (name, s) in &st.per_model {
        let mut m = Json::obj();
        m.set("count", Json::Num(s.count as f64));
        m.set("p50_ms", Json::Num(s.p50));
        m.set("p99_ms", Json::Num(s.p99));
        models.set(name, m);
    }
    o.set("per_model", models);
    o.to_string()
}

/// Fresh per-request seed for synthesized `"random"` inputs.
static INFER_SEED: AtomicU64 = AtomicU64::new(0x9e37);

fn handle_infer(server: &Server, body: &str) -> (u16, &'static str, String) {
    let parsed = if body.trim().is_empty() {
        Json::obj()
    } else {
        match json::parse(body) {
            Ok(j) => j,
            Err(e) => return (400, "application/json", err_json(&format!("bad json: {e}"))),
        }
    };
    let model = parsed.get("model").and_then(|m| m.as_str()).map(str::to_string);
    let deadline = parsed.get("deadline_ms").and_then(|d| d.as_f64());
    let shape: Option<Vec<usize>> = parsed
        .get("shape")
        .and_then(|s| s.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect());
    // The target model's compiled input shape backs `"random"` inputs
    // and validates explicit ones; unknown for non-resident models (the
    // client must then send an explicit shape).
    let model_shape: Option<Vec<usize>> = model
        .as_deref()
        .or(server.default_model())
        .and_then(|n| server.registry().get(n))
        .map(|e| e.plan().memory.shapes[e.plan().input_id].clone());
    let input = match parsed.get("input") {
        Some(Json::Arr(vals)) => {
            let data: Vec<f32> = vals.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
            if data.len() != vals.len() {
                return (400, "application/json", err_json("input must be an array of numbers"));
            }
            let Some(dims) = shape.or(model_shape) else {
                return (400, "application/json", err_json("model is not resident; send \"shape\""));
            };
            if dims.iter().product::<usize>() != data.len() {
                return (
                    400,
                    "application/json",
                    err_json(&format!("input has {} values but shape {dims:?} needs {}",
                        data.len(), dims.iter().product::<usize>())),
                );
            }
            Tensor::from_vec(&dims, data)
        }
        None | Some(Json::Str(_)) => {
            // "random" (or omitted): synthesize — the smoke-test path.
            let Some(dims) = shape.or(model_shape) else {
                return (400, "application/json", err_json("model is not resident; send \"shape\""));
            };
            let mut rng = Rng::new(INFER_SEED.fetch_add(1, Ordering::Relaxed));
            Tensor::rand_uniform(&dims, 1.0, &mut rng)
        }
        Some(_) => {
            return (400, "application/json", err_json("input must be an array or \"random\""))
        }
    };
    let submitted = match deadline {
        Some(ms) => server.submit_with_deadline(
            model.as_deref(),
            input,
            Duration::from_secs_f64((ms / 1e3).max(0.0)),
        ),
        None => match &model {
            Some(m) => server.submit_to(m, input),
            None => server.submit(input),
        },
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(e) => return (503, "application/json", err_json(&e.to_string())),
    };
    let resp = match rx.recv_timeout(INFER_TIMEOUT) {
        Ok(r) => r,
        // E.g. a background model load that never completes: free this
        // handler thread (and its permit) instead of pinning it forever.
        // The coordinator's eventual response is dropped harmlessly.
        Err(RecvTimeoutError::Timeout) => {
            return (504, "application/json", err_json("timed out waiting for inference response"))
        }
        Err(RecvTimeoutError::Disconnected) => {
            return (500, "application/json", err_json("server dropped request"))
        }
    };
    if let Some(err) = &resp.error {
        let status = match err {
            ServeError::DeadlineExceeded => 504,
            ServeError::ModelNotResident { .. } | ServeError::NoDefaultModel => 404,
            ServeError::Exec(_) => 400,
        };
        return (status, "application/json", err_json(&err.to_string()));
    }
    let mut o = Json::obj();
    o.set("id", Json::Num(resp.id as f64));
    o.set("argmax", Json::Num(resp.output.argmax() as f64));
    o.set("numel", Json::Num(resp.output.numel() as f64));
    o.set("output", json::num_arr(resp.output.data().iter().map(|&x| x as f64)));
    o.set("queue_ms", Json::Num(resp.queue_ms));
    o.set("batch_ms", Json::Num(resp.batch_ms));
    o.set("exec_ms", Json::Num(resp.exec_ms));
    (200, "application/json", o.to_string())
}

fn err_json(msg: &str) -> String {
    let mut o = Json::obj();
    o.set("error", Json::Str(msg.to_string()));
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::engine::Engine;
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::coordinator::ServerConfig;

    fn small_server() -> Arc<Server> {
        let opts = InitOptions { rate: 4.0, block: [4, 16], seed: 3 };
        let m = build_model(ModelKind::Gru, Preset::TimitMini, opts);
        let w = random_weights(&m, opts);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        Arc::new(Server::start(Engine::new(plan, 2), ServerConfig::default()))
    }

    fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
        http_request(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
    }

    fn http_request(addr: SocketAddr, raw: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let status: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
        let body = resp.split("\r\n\r\n").nth(1).unwrap_or_default().to_string();
        (status, body)
    }

    #[test]
    fn http_end_to_end() {
        let server = small_server();
        let http = HttpServer::start(Arc::clone(&server), "127.0.0.1:0").unwrap();
        let addr = http.local_addr();

        let (status, body) = http_get(addr, "/healthz");
        assert_eq!(status, 200);
        assert!(body.contains("ok"), "{body}");

        // Random-input inference — the curl-smoke path: no payload
        // beyond an empty JSON object.
        let req = "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 2\r\n\r\n{}";
        let (status, body) = http_request(addr, req);
        assert_eq!(status, 200, "{body}");
        let j = json::parse(&body).unwrap();
        assert_eq!(j.get("numel").and_then(|n| n.as_usize()), Some(40));

        // Explicit input with the wrong element count is a 400, not a
        // panic or a 200 with garbage.
        let bad = r#"{"input": [1.0, 2.0], "shape": [3]}"#;
        let req =
            format!("POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{bad}", bad.len());
        let (status, body) = http_request(addr, &req);
        assert_eq!(status, 400, "{body}");

        // Unknown model → typed 404 (no artifact dir, nothing to load).
        let miss = r#"{"model": "nope", "shape": [4]}"#;
        let req = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{miss}",
            miss.len()
        );
        let (status, body) = http_request(addr, &req);
        assert_eq!(status, 404, "{body}");
        assert!(body.contains("not resident"), "{body}");

        let (status, body) = http_get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("grim_dispatch_lanes"), "{body}");
        let (status, body) = http_get(addr, "/stats");
        assert_eq!(status, 200);
        assert!(body.contains("dispatch_lanes"), "{body}");

        let (status, _) = http_get(addr, "/nope");
        assert_eq!(status, 404);

        assert!(http.handled() >= 6);
        http.shutdown();
    }
}
