//! The L3 serving coordinator: request queue → dynamic batcher → worker
//! pool → response collection, with latency/throughput metrics.
//!
//! GRIM's paper targets single-stream real-time inference (30 fps); a
//! deployed mobile runtime still multiplexes streams (camera + audio), so
//! the coordinator provides the full serving loop: bounded queueing with
//! backpressure, deadline-aware batching, concurrent multi-model dispatch
//! over a pool of lanes ([`server`]), registry-aware admission control
//! with background artifact loads ([`admission`]), an HTTP/JSON ingress
//! ([`http`]), and per-request latency percentiles. This is the request
//! path — all-Rust, no Python.

pub mod queue;
pub mod batcher;
pub(crate) mod admission;
pub mod server;
pub mod http;

pub use batcher::{Batch, Batcher, BatchPolicy};
pub use http::HttpServer;
pub use queue::{InferRequest, InferResponse, RequestQueue, ServeError};
pub use server::{Server, ServerConfig, ServerStats};
