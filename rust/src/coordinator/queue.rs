//! Bounded MPSC request queue with backpressure.

use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// One inference request.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    pub input: Tensor,
    pub enqueued: Instant,
}

/// One inference response.
#[derive(Debug)]
pub struct InferResponse {
    pub id: u64,
    /// Model output; a zero placeholder when `error` is set.
    pub output: Tensor,
    /// Time spent waiting in the queue (ms).
    pub queue_ms: f64,
    /// Time spent executing (ms).
    pub exec_ms: f64,
    /// Execution failure (e.g. wrong input shape); `None` on success.
    pub error: Option<String>,
}

/// A bounded FIFO with blocking push (backpressure) and blocking pop.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner {
    q: VecDeque<InferRequest>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns Err if the queue is closed.
    pub fn push(&self, req: InferRequest) -> Result<(), InferRequest> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(req);
        }
        g.q.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push; Err(req) when full or closed.
    pub fn try_push(&self, req: InferRequest) -> Result<(), InferRequest> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.q.len() >= self.capacity {
            return Err(req);
        }
        g.q.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop one request, blocking until available or closed+drained.
    pub fn pop(&self) -> Option<InferRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Drain up to `max` requests without blocking (used by the batcher
    /// after it got the first request).
    pub fn drain_up_to(&self, max: usize) -> Vec<InferRequest> {
        let mut g = self.inner.lock().unwrap();
        let take = g.q.len().min(max);
        let out: Vec<_> = g.q.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pushes fail, pops drain the remainder then return None.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> InferRequest {
        InferRequest { id, input: Tensor::zeros(&[1]), enqueued: Instant::now() }
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().id, i);
        }
    }

    #[test]
    fn try_push_full() {
        let q = RequestQueue::new(2);
        q.try_push(req(0)).unwrap();
        q.try_push(req(1)).unwrap();
        assert!(q.try_push(req(2)).is_err());
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(req(1)).unwrap();
        q.close();
        assert!(q.push(req(2)).is_err());
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_unblocks() {
        let q = Arc::new(RequestQueue::new(1));
        q.push(req(0)).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(req(1)).is_ok());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop().unwrap().id, 0); // frees a slot
        assert!(h.join().unwrap());
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn drain_up_to_respects_max() {
        let q = RequestQueue::new(8);
        for i in 0..6 {
            q.push(req(i)).unwrap();
        }
        let batch = q.drain_up_to(4);
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }
}
