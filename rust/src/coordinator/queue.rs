//! Bounded MPSC request queue with backpressure, plus the typed
//! serving-error taxonomy responses carry.

use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a request failed, as a typed variant rather than a formatted
/// string — admission control and per-model miss counters hook on
/// [`ServeError::ModelNotResident`] without parsing messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request named a model the registry does not currently hold
    /// (never loaded, or LRU-evicted while the request sat queued) and
    /// admission control could not park it for a background load
    /// (no artifact on disk, pending queue full, or the load failed).
    ModelNotResident { model: String },
    /// The request named no model and the server has no default.
    NoDefaultModel,
    /// The request's deadline passed before a dispatcher lane picked it
    /// up — the scheduler drops dead work at dequeue instead of burning
    /// kernel time on an answer nobody is waiting for.
    DeadlineExceeded,
    /// The target engine rejected or failed the request.
    Exec(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::ModelNotResident { model } => {
                write!(f, "model '{model}' is not resident (unknown or evicted)")
            }
            ServeError::NoDefaultModel => {
                write!(f, "request names no model and the server has no default")
            }
            ServeError::DeadlineExceeded => {
                write!(f, "request deadline expired before dispatch")
            }
            ServeError::Exec(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One inference request.
#[derive(Debug)]
pub struct InferRequest {
    pub id: u64,
    /// Target model name for multi-model routing; `None` routes to the
    /// server's default model (single-model servers).
    pub model: Option<String>,
    pub input: Tensor,
    pub enqueued: Instant,
    /// Drop-dead time: a dispatcher lane that dequeues the request after
    /// this instant responds [`ServeError::DeadlineExceeded`] without
    /// executing it. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Set when admission control re-enqueued the request after a
    /// background model load — a second miss then fails immediately
    /// instead of parking again (bounds the park→load→evict loop).
    pub requeued: bool,
}

/// One inference response.
#[derive(Debug)]
pub struct InferResponse {
    pub id: u64,
    /// Model output; a zero placeholder when `error` is set.
    pub output: Tensor,
    /// Time from enqueue until the request's batch was formed (ms).
    pub queue_ms: f64,
    /// Batch-formation window of the request's batch (ms) — how long the
    /// batcher held the first request while gathering companions; the
    /// same value for every request in one batch.
    pub batch_ms: f64,
    /// Time spent executing (ms).
    pub exec_ms: f64,
    /// Typed failure (non-resident model, engine error); `None` on
    /// success.
    pub error: Option<ServeError>,
}

/// A bounded FIFO with blocking push (backpressure) and blocking pop.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner {
    q: VecDeque<InferRequest>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        RequestQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns Err if the queue is closed.
    pub fn push(&self, req: InferRequest) -> Result<(), InferRequest> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(req);
        }
        g.q.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push; Err(req) when full or closed.
    pub fn try_push(&self, req: InferRequest) -> Result<(), InferRequest> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.q.len() >= self.capacity {
            return Err(req);
        }
        g.q.push_back(req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop one request, blocking until available or closed+drained.
    pub fn pop(&self) -> Option<InferRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(r) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(r);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Drain the longest front prefix (≤ `max`) whose requests satisfy
    /// `matches` — the single drain implementation both public variants
    /// share.
    fn drain_prefix(
        &self,
        max: usize,
        matches: impl Fn(&InferRequest) -> bool,
    ) -> Vec<InferRequest> {
        let mut g = self.inner.lock().unwrap();
        let mut take = 0usize;
        while take < max && take < g.q.len() && matches(&g.q[take]) {
            take += 1;
        }
        let out: Vec<_> = g.q.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Drain up to `max` requests without blocking, regardless of model.
    pub fn drain_up_to(&self, max: usize) -> Vec<InferRequest> {
        self.drain_prefix(max, |_| true)
    }

    /// Drain up to `max` requests from the front **while they target
    /// `model`** (FIFO order preserved; a batch never mixes models). A
    /// head-of-line request for another model stops the drain — it will
    /// seed the next batch.
    pub fn drain_while_matching(&self, max: usize, model: &Option<String>) -> Vec<InferRequest> {
        self.drain_prefix(max, |r| r.model == *model)
    }

    /// Does the head request target `model`? `None` when the queue is
    /// empty (the batcher uses `Some(false)` to ship a batch early rather
    /// than waiting out its deadline behind another model's request).
    pub fn front_matches(&self, model: &Option<String>) -> Option<bool> {
        let g = self.inner.lock().unwrap();
        g.q.front().map(|r| r.model == *model)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: pushes fail, pops drain the remainder then return None.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> InferRequest {
        InferRequest {
            id,
            model: None,
            input: Tensor::zeros(&[1]),
            enqueued: Instant::now(),
            deadline: None,
            requeued: false,
        }
    }

    fn req_for(id: u64, model: &str) -> InferRequest {
        InferRequest { model: Some(model.to_string()), ..req(id) }
    }

    #[test]
    fn drain_while_matching_stops_at_other_model() {
        let q = RequestQueue::new(8);
        q.push(req_for(0, "a")).unwrap();
        q.push(req_for(1, "a")).unwrap();
        q.push(req_for(2, "b")).unwrap();
        q.push(req_for(3, "a")).unwrap();
        let a = Some("a".to_string());
        let got = q.drain_while_matching(8, &a);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(q.front_matches(&a), Some(false), "model-b request now heads the queue");
        assert_eq!(q.len(), 2, "mismatched requests stay queued in order");
        assert_eq!(q.drain_while_matching(8, &Some("b".to_string()))[0].id, 2);
    }

    #[test]
    fn front_matches_empty_queue() {
        let q = RequestQueue::new(2);
        assert_eq!(q.front_matches(&None), None);
        q.push(req(1)).unwrap();
        assert_eq!(q.front_matches(&None), Some(true));
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop().unwrap().id, i);
        }
    }

    #[test]
    fn try_push_full() {
        let q = RequestQueue::new(2);
        q.try_push(req(0)).unwrap();
        q.try_push(req(1)).unwrap();
        assert!(q.try_push(req(2)).is_err());
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4);
        q.push(req(1)).unwrap();
        q.close();
        assert!(q.push(req(2)).is_err());
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_unblocks() {
        let q = Arc::new(RequestQueue::new(1));
        q.push(req(0)).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(req(1)).is_ok());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop().unwrap().id, 0); // frees a slot
        assert!(h.join().unwrap());
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn drain_up_to_respects_max() {
        let q = RequestQueue::new(8);
        for i in 0..6 {
            q.push(req(i)).unwrap();
        }
        let batch = q.drain_up_to(4);
        assert_eq!(batch.len(), 4);
        assert_eq!(q.len(), 2);
    }
}
