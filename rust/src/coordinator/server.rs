//! The serving loop: a batch-former thread pulls model-homogeneous
//! batches from the request queue and ships them over a bounded channel
//! to a pool of **dispatcher lanes**, which execute batches concurrently
//! on the shared [`crate::exec::Runtime`]; clients submit via a handle
//! and receive responses over per-request channels.
//!
//! Concurrency model (PR 8): with `N` resident models and `L` dispatcher
//! lanes (`ServerConfig::max_inflight`, default = resident-model count
//! clamped to the runtime width), up to `L` batches execute at once —
//! per-model runtime quotas now bound genuinely overlapping kernel
//! fan-out instead of sequential slices. `L = 1` (or
//! `GRIM_SERIAL_DISPATCH=1`) restores the old serial dispatch exactly:
//! one lane thread executes every batch in arrival order.
//!
//! Routing is by model name, threaded end to end through the
//! coordinator: every [`InferRequest`] names its target model (or `None`
//! for the server's default), the batcher forms model-homogeneous
//! batches, and each lane resolves its batch's name against a
//! [`ModelRegistry`] at execution time. A request for a **non-resident**
//! model whose artifact exists in the registry's artifact directory is
//! parked by the admission controller ([`super::admission`]) while the
//! model loads on a background thread, then re-enqueued — the typed
//! [`ServeError::ModelNotResident`] is reserved for models that cannot
//! be made resident. Requests carrying a deadline are dropped at
//! dequeue with [`ServeError::DeadlineExceeded`] instead of running dead
//! work. A quota governor (when `ServerConfig::slo_ms` names targets)
//! widens or narrows per-model runtime quotas to chase p99 latency SLOs.

use super::admission::{self, Admission};
use super::batcher::{Batch, Batcher, BatchPolicy};
use super::queue::{InferRequest, InferResponse, RequestQueue, ServeError};
use crate::engine::Engine;
use crate::memory::{PoolStats, WorkspacePool};
use crate::obs::trace::{self, SpanKind};
use crate::obs::{Counter, Gauge, Histogram, HistogramWindow, Registry};
use crate::serving::ModelRegistry;
use crate::tensor::Tensor;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Dispatcher lanes = maximum concurrently executing batches.
    /// `None` resolves at start to the resident-model count clamped to
    /// the runtime's worker count (min 1). `Some(1)` — or the
    /// `GRIM_SERIAL_DISPATCH=1` env override, which wins over any
    /// setting — forces the old serial dispatch.
    pub max_inflight: Option<usize>,
    /// Per-model p99 latency targets in ms (`--slo-ms m=N`): a governor
    /// thread widens the model's runtime quota while its observed p99
    /// exceeds the target and narrows it while p99 sits under half the
    /// target. Quota changes are pure schedule metadata (PR 5).
    pub slo_ms: Vec<(String, f64)>,
    /// Requests parked awaiting background model loads, across all
    /// models; overflow fails with the typed not-resident error.
    pub pending_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            max_inflight: None,
            slo_ms: Vec::new(),
            pending_cap: 256,
        }
    }
}

/// Response-channel map: request id → the sender its response goes to.
pub(crate) type PendingMap = Mutex<HashMap<u64, Sender<InferResponse>>>;

/// Answer `req` with a typed error (used by dispatcher lanes and the
/// admission controller's loader threads). A missing sender means the
/// client dropped its receiver — nothing to do.
pub(crate) fn respond_error(pending: &PendingMap, req: &InferRequest, error: ServeError) {
    let tx = pending.lock().unwrap().remove(&req.id);
    if let Some(tx) = tx {
        let _ = tx.send(admission::error_response(req, error));
    }
}

/// Aggregated serving statistics. Summaries come from bounded
/// log₂-bucketed histograms ([`crate::obs::Histogram`]), not an
/// unbounded sample vector — count/mean/min/max are exact,
/// p50/p90/p99 are bucket estimates.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub batches: u64,
    /// End-to-end request latency (enqueue → response ready).
    pub latency_ms: Summary,
    /// Queue wait (enqueue → the request's batch formed).
    pub queue_ms: Summary,
    /// Engine execution time.
    pub exec_ms: Summary,
    /// Batch-formation window (one sample per batch).
    pub batch_form_ms: Summary,
    /// Batch-size distribution (one sample per batch, unitless).
    pub batch_size: Summary,
    pub throughput_rps: f64,
    /// Requests that failed (wrong shape, unknown model, plan errors,
    /// expired deadlines). These are excluded from `completed` and from
    /// the latency/throughput summaries so a burst of fast failures
    /// cannot flatter the stats; `completed + failed` = total responses.
    pub failed: u64,
    /// Requests dropped at dequeue because their deadline had passed
    /// (a subset of `failed`, also counted per model in
    /// `grim_requests_expired_total`).
    pub expired: u64,
    /// Dispatcher lanes — the concurrent-batch ceiling.
    pub dispatch_lanes: usize,
    /// Workspace-arena pool telemetry of the *default* model (zeroed for
    /// registry servers without one — use `ModelRegistry::stats` for the
    /// per-model breakdown).
    pub arena: PoolStats,
    /// Per-model end-to-end latency summaries (ms), sorted by model
    /// name; unnamed-default traffic appears under the default model's
    /// name.
    pub per_model: Vec<(String, Summary)>,
}

/// A running inference server over one or many compiled models.
pub struct Server {
    queue: Arc<RequestQueue>,
    next_id: AtomicU64,
    pending: Arc<PendingMap>,
    /// Batch former + dispatcher lanes (+ governor), joined on shutdown.
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Per-model labeled series (latency/queue/exec/batch/step
    /// histograms + completion counters) — the Prometheus surface.
    metrics: Arc<Registry>,
    /// Server-wide histograms, kept out of the registry so the labeled
    /// per-model families stay label-consistent in the text dump.
    hist_latency: Arc<Histogram>,
    hist_queue: Arc<Histogram>,
    hist_exec: Arc<Histogram>,
    hist_batch_form: Arc<Histogram>,
    hist_batch_size: Arc<Histogram>,
    started: Instant,
    completed: Arc<AtomicU64>,
    failed: Arc<AtomicU64>,
    expired: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    /// Batches currently executing on dispatcher lanes.
    inflight: Arc<Gauge>,
    /// The model registry requests are resolved against (shared: models
    /// can be hot-loaded/evicted while serving).
    registry: Arc<ModelRegistry>,
    /// Model served when a request names none ([`Self::start`] sets it).
    default_model: Option<String>,
    /// The default model's workspace pool, kept observable for stats.
    arena: Option<Arc<WorkspacePool>>,
    admission: Arc<Admission>,
    lanes: usize,
    governor_stop: Arc<AtomicBool>,
}

/// Cached per-model metric handles: one registry-mutex hit per new
/// model (and per new kernel kind), pure atomics in steady state.
struct ModelHists {
    latency: Arc<Histogram>,
    queue: Arc<Histogram>,
    exec: Arc<Histogram>,
    batch_size: Arc<Histogram>,
    /// Batch formed → a dispatcher lane picked it up (µs).
    dispatch_wait: Arc<Histogram>,
    completed: Arc<Counter>,
    failed: Arc<Counter>,
    expired: Arc<Counter>,
    steps: HashMap<&'static str, Arc<Histogram>>,
    trace_id: u32,
}

impl ModelHists {
    fn new(reg: &Registry, model: &str) -> Self {
        let l: &[(&str, &str)] = &[("model", model)];
        ModelHists {
            latency: reg.histogram("grim_request_latency_us", l),
            queue: reg.histogram("grim_queue_wait_us", l),
            exec: reg.histogram("grim_exec_time_us", l),
            batch_size: reg.histogram("grim_batch_size", l),
            dispatch_wait: reg.histogram("grim_dispatch_wait_us", l),
            completed: reg.counter("grim_requests_completed_total", l),
            failed: reg.counter("grim_requests_failed_total", l),
            expired: reg.counter("grim_requests_expired_total", l),
            steps: HashMap::new(),
            trace_id: 0,
        }
    }

    /// Step-time histogram for one kernel kind, registered on first use.
    fn step(&mut self, reg: &Registry, model: &str, kind: &'static str) -> &Histogram {
        self.steps.entry(kind).or_insert_with(|| {
            reg.histogram("grim_step_time_us", &[("model", model), ("kind", kind)])
        })
    }

    /// Interned trace id of the model label, resolved on the first
    /// sampled batch (never on the tracing-off path).
    fn trace_id(&mut self, model: &str) -> u32 {
        if self.trace_id == 0 {
            self.trace_id = trace::intern(model);
        }
        self.trace_id
    }
}

/// Everything a dispatcher lane shares with its peers; per-lane state
/// (the `ModelHists` cache) stays thread-local.
struct LaneShared {
    pending: Arc<PendingMap>,
    metrics: Arc<Registry>,
    registry: Arc<ModelRegistry>,
    default_model: Option<String>,
    admission: Arc<Admission>,
    inflight: Arc<Gauge>,
    /// Default model's workspace pool, sampled into trace counter
    /// tracks (`arena_bytes`) on sampled batches.
    arena: Option<Arc<WorkspacePool>>,
    /// Roofline denominator for per-model gauges, resolved once at
    /// server start.
    machine: crate::obs::prof::MachineModel,
    hist_latency: Arc<Histogram>,
    hist_queue: Arc<Histogram>,
    hist_exec: Arc<Histogram>,
    hist_batch_form: Arc<Histogram>,
    hist_batch_size: Arc<Histogram>,
    completed: Arc<AtomicU64>,
    failed: Arc<AtomicU64>,
    expired: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
}

impl Server {
    /// Start a single-model server: `engine` becomes the registry's sole
    /// entry and the default route.
    pub fn start(engine: Engine, config: ServerConfig) -> Self {
        let name = engine.plan().name.clone();
        // The one-model registry borrows the engine's runtime — no
        // second worker pool is spawned.
        let registry = Arc::new(ModelRegistry::with_runtime(engine.runtime(), usize::MAX));
        let arena = engine.workspace_pool();
        registry.insert_engine(name.clone(), engine);
        Self::start_inner(registry, Some(name), Some(arena), config)
    }

    /// Start a multi-model server over a shared registry. Requests must
    /// name their model ([`Self::submit_to`] / [`Self::infer_on`]).
    pub fn start_registry(registry: Arc<ModelRegistry>, config: ServerConfig) -> Self {
        Self::start_inner(registry, None, None, config)
    }

    /// Resolve the dispatcher-lane count: explicit config (floored at 1)
    /// beats the default of one lane per resident model clamped to the
    /// runtime width; `GRIM_SERIAL_DISPATCH=1` beats everything.
    fn resolve_lanes(registry: &ModelRegistry, config: &ServerConfig) -> usize {
        if std::env::var("GRIM_SERIAL_DISPATCH").is_ok_and(|v| v == "1") {
            return 1;
        }
        match config.max_inflight {
            Some(n) => n.max(1),
            None => registry.len().clamp(1, registry.runtime().threads().max(1)),
        }
    }

    fn start_inner(
        registry: Arc<ModelRegistry>,
        default_model: Option<String>,
        arena: Option<Arc<WorkspacePool>>,
        config: ServerConfig,
    ) -> Self {
        let queue = Arc::new(RequestQueue::new(config.queue_capacity));
        let pending: Arc<PendingMap> = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(Registry::new());
        let lanes = Self::resolve_lanes(&registry, &config);
        metrics.gauge("grim_dispatch_lanes", &[]).set(lanes as u64);
        let inflight = metrics.gauge("grim_inflight_batches", &[]);
        // Pre-register both background-load outcomes so the series show
        // up (at 0) in scrapes before the first cold-model request.
        let loads_ok = metrics.counter("grim_background_loads_total", &[("result", "ok")]);
        let loads_failed = metrics.counter("grim_background_loads_total", &[("result", "failed")]);
        // Shared with the admission controller: requests it fails on the
        // load path count exactly like lane failures.
        let failed = Arc::new(AtomicU64::new(0));
        let admission = Admission::new(
            Arc::clone(&registry),
            Arc::clone(&queue),
            Arc::clone(&pending),
            config.pending_cap,
            loads_ok,
            loads_failed,
            Arc::clone(&metrics),
            Arc::clone(&failed),
        );
        let shared = Arc::new(LaneShared {
            pending: Arc::clone(&pending),
            metrics: Arc::clone(&metrics),
            registry: Arc::clone(&registry),
            default_model: default_model.clone(),
            admission: Arc::clone(&admission),
            inflight: Arc::clone(&inflight),
            arena: arena.clone(),
            machine: crate::obs::prof::MachineModel::detect(registry.runtime().threads()),
            hist_latency: Arc::new(Histogram::new()),
            hist_queue: Arc::new(Histogram::new()),
            hist_exec: Arc::new(Histogram::new()),
            hist_batch_form: Arc::new(Histogram::new()),
            hist_batch_size: Arc::new(Histogram::new()),
            completed: Arc::new(AtomicU64::new(0)),
            failed,
            expired: Arc::new(AtomicU64::new(0)),
            batches: Arc::new(AtomicU64::new(0)),
        });

        let mut workers = Vec::with_capacity(lanes + 2);

        // --- batch former: queue → bounded batch channel ---------------
        // The channel is the inflight bound: `lanes` executing + up to
        // `lanes` formed-and-waiting batches; dispatch_wait measures the
        // formed → picked-up gap.
        let (batch_tx, batch_rx) = sync_channel::<Batch>(lanes);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        {
            let q2 = Arc::clone(&queue);
            let preg = Arc::clone(&registry);
            let pdefault = default_model.clone();
            let policy = config.batch;
            workers.push(
                std::thread::Builder::new()
                    .name("grim-batcher".into())
                    .spawn(move || {
                        // Per-model batching: the registry's policy
                        // overrides win over the server-wide default,
                        // resolved per batch head (unnamed requests
                        // resolve through the default model's name).
                        let rreg = Arc::clone(&preg);
                        let rdefault = pdefault.clone();
                        let batcher = Batcher::with_policy_resolver(
                            &q2,
                            policy,
                            Box::new(move |m| {
                                let name = m.or(rdefault.as_deref())?;
                                rreg.policy_for(name)
                            }),
                        );
                        while let Some(batch) = batcher.next_batch() {
                            if batch_tx.send(batch).is_err() {
                                break; // lanes gone
                            }
                        }
                        // Dropping batch_tx closes the channel; lanes
                        // drain what is buffered and exit.
                    })
                    .expect("spawn batch former"),
            );
        }

        // --- dispatcher lanes: batch channel → engines ------------------
        for lane in 0..lanes {
            let shared = Arc::clone(&shared);
            let rx = Arc::clone(&batch_rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("grim-dispatch-{lane}"))
                    .spawn(move || {
                        // Per-lane metric-handle cache (handles resolve
                        // to the same shared atomics in the registry).
                        let mut hists: HashMap<String, ModelHists> = HashMap::new();
                        loop {
                            // Exactly one idle lane blocks in recv()
                            // while holding the lock; the others queue on
                            // the mutex — batches hand off one at a time.
                            let batch = { rx.lock().unwrap().recv() };
                            match batch {
                                Ok(b) => process_batch(&shared, &mut hists, b),
                                Err(_) => break, // former exited
                            }
                        }
                    })
                    .expect("spawn dispatcher lane"),
            );
        }

        // --- quota governor: per-model p99 vs SLO → runtime quotas ------
        let governor_stop = Arc::new(AtomicBool::new(false));
        if !config.slo_ms.is_empty() {
            let stop = Arc::clone(&governor_stop);
            let reg = Arc::clone(&registry);
            let m2 = Arc::clone(&metrics);
            let slo = config.slo_ms.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("grim-governor".into())
                    .spawn(move || run_governor(&stop, &reg, &m2, &slo))
                    .expect("spawn quota governor"),
            );
        }

        Server {
            queue,
            next_id: AtomicU64::new(1),
            pending,
            workers,
            metrics,
            hist_latency: Arc::clone(&shared.hist_latency),
            hist_queue: Arc::clone(&shared.hist_queue),
            hist_exec: Arc::clone(&shared.hist_exec),
            hist_batch_form: Arc::clone(&shared.hist_batch_form),
            hist_batch_size: Arc::clone(&shared.hist_batch_size),
            started: Instant::now(),
            completed: Arc::clone(&shared.completed),
            failed: Arc::clone(&shared.failed),
            expired: Arc::clone(&shared.expired),
            batches: Arc::clone(&shared.batches),
            inflight,
            registry,
            default_model,
            arena,
            admission,
            lanes,
            governor_stop,
        }
    }

    /// The registry this server routes over (hot-load models through it).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Dispatcher-lane count — the concurrent-batch ceiling this server
    /// was started with.
    pub fn dispatch_lanes(&self) -> usize {
        self.lanes
    }

    /// Batches executing on dispatcher lanes right now.
    pub fn inflight_batches(&self) -> u64 {
        self.inflight.get()
    }

    fn enqueue(
        &self,
        model: Option<String>,
        input: Tensor,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Receiver<InferResponse>> {
        // Normalize an explicit request for the default model to `None`
        // so it batches with unnamed requests (the batcher groups by the
        // literal model field; without this, mixing submit() and
        // submit_to(default) would fragment every batch).
        let model = match (&self.default_model, model) {
            (Some(d), Some(m)) if *d == m => None,
            (_, m) => m,
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(id, tx);
        let now = Instant::now();
        self.queue
            .push(InferRequest {
                id,
                model,
                input,
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                requeued: false,
            })
            .map_err(|req| {
                // Closed queue: retire the parked sender so the map
                // cannot grow on a rejected submit.
                self.pending.lock().unwrap().remove(&req.id);
                anyhow::anyhow!("server closed")
            })?;
        Ok(rx)
    }

    /// Submit a request to the default model; returns a receiver for the
    /// response. Blocks (backpressure) when the queue is full.
    pub fn submit(&self, input: Tensor) -> anyhow::Result<Receiver<InferResponse>> {
        self.enqueue(None, input, None)
    }

    /// Submit a request routed to the named model.
    pub fn submit_to(&self, model: &str, input: Tensor) -> anyhow::Result<Receiver<InferResponse>> {
        self.enqueue(Some(model.to_string()), input, None)
    }

    /// Submit with a drop-dead deadline (relative to now): if no
    /// dispatcher lane picks the request up in time it is answered with
    /// [`ServeError::DeadlineExceeded`] instead of executing.
    /// `model = None` routes to the default model.
    pub fn submit_with_deadline(
        &self,
        model: Option<&str>,
        input: Tensor,
        deadline: Duration,
    ) -> anyhow::Result<Receiver<InferResponse>> {
        self.enqueue(model.map(str::to_string), input, Some(deadline))
    }

    /// Submit and wait for the response (convenience). Execution
    /// failures surface as `Err`, never as a placeholder output.
    pub fn infer(&self, input: Tensor) -> anyhow::Result<InferResponse> {
        Self::wait(self.submit(input)?)
    }

    /// Submit to the named model and wait for the response.
    pub fn infer_on(&self, model: &str, input: Tensor) -> anyhow::Result<InferResponse> {
        Self::wait(self.submit_to(model, input)?)
    }

    fn wait(rx: Receiver<InferResponse>) -> anyhow::Result<InferResponse> {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?;
        if let Some(e) = &resp.error {
            anyhow::bail!("inference failed: {e}");
        }
        Ok(resp)
    }

    /// Current stats snapshot.
    pub fn stats(&self) -> ServerStats {
        let completed = self.completed.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut per_model: Vec<(String, Summary)> = self
            .metrics
            .histograms_named("grim_request_latency_us")
            .into_iter()
            .map(|(labels, h)| {
                let name = labels
                    .iter()
                    .find(|(k, _)| k == "model")
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                (name, h.summary(1e-3))
            })
            .collect();
        per_model.sort_by(|a, b| a.0.cmp(&b.0));
        ServerStats {
            completed,
            batches: self.batches.load(Ordering::Relaxed),
            latency_ms: self.hist_latency.summary(1e-3),
            queue_ms: self.hist_queue.summary(1e-3),
            exec_ms: self.hist_exec.summary(1e-3),
            batch_form_ms: self.hist_batch_form.summary(1e-3),
            batch_size: self.hist_batch_size.summary(1.0),
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            failed: self.failed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            dispatch_lanes: self.lanes,
            arena: self.arena.as_ref().map(|a| a.stats()).unwrap_or_default(),
            per_model,
        }
    }

    /// The server's metric registry (per-model labeled series).
    pub fn metrics(&self) -> Arc<Registry> {
        Arc::clone(&self.metrics)
    }

    /// Render the full metrics surface in Prometheus text exposition
    /// format: per-model labeled series from the registry (including
    /// `grim_dispatch_wait_us`, `grim_inflight_batches`,
    /// `grim_background_loads_total`, `grim_requests_expired_total`),
    /// server-level counters/uptime, and the model registry's
    /// resident/arena/quota gauges. `grim serve --stats-out` writes
    /// this; `grim stats` parses it back.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = self.metrics.render();
        let _ = writeln!(out, "# TYPE grim_server_requests_completed_total counter");
        let _ = writeln!(
            out,
            "grim_server_requests_completed_total {}",
            self.completed.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_requests_failed_total counter");
        let _ = writeln!(
            out,
            "grim_server_requests_failed_total {}",
            self.failed.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_requests_expired_total counter");
        let _ = writeln!(
            out,
            "grim_server_requests_expired_total {}",
            self.expired.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_batches_total counter");
        let _ = writeln!(
            out,
            "grim_server_batches_total {}",
            self.batches.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_uptime_seconds gauge");
        let _ = writeln!(
            out,
            "grim_server_uptime_seconds {:.3}",
            self.started.elapsed().as_secs_f64()
        );
        self.registry.render_prometheus_into(&mut out);
        out
    }

    /// Stop accepting requests, drain in-flight work, join every worker
    /// thread, and flush admission-parked requests.
    fn stop_workers(&mut self) {
        self.queue.close();
        self.governor_stop.store(true, Ordering::Relaxed);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // After the lanes are gone nothing will re-dispatch re-enqueued
        // requests; loader threads answer them directly (closed queue),
        // and anything still parked is failed here.
        self.admission.shutdown();
    }

    /// Stop accepting requests, drain, and join the workers.
    pub fn shutdown(mut self) -> ServerStats {
        self.stop_workers();
        self.stats()
    }

    /// The default model's name, when this server has one.
    pub fn default_model(&self) -> Option<&str> {
        self.default_model.as_deref()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_workers();
    }
}

/// Execute one model-homogeneous batch on a dispatcher lane: resolve the
/// model, run admission control for non-resident targets, drop expired
/// requests, execute the rest, and answer every response channel.
fn process_batch(shared: &LaneShared, hists: &mut HashMap<String, ModelHists>, mut batch: Batch) {
    let picked = Instant::now();
    shared.batches.fetch_add(1, Ordering::Relaxed);
    // Batches are model-homogeneous; resolve once per batch, at
    // execution time — a model evicted while its requests sat in the
    // queue fails them loudly instead of silently pinning its memory.
    let target = batch.reqs[0].model.clone().or_else(|| shared.default_model.clone());
    let mut engine = target.as_deref().and_then(|n| shared.registry.get(n));
    if engine.is_none() {
        if let Some(n) = target.as_deref() {
            // One miss per failed request (batched: one lock); the
            // counter is the admission-control signal.
            shared.registry.note_misses(n, batch.len() as u64);
            // Park what can be parked for a background artifact load;
            // only the rejects fall through to the typed error.
            let reqs = std::mem::take(&mut batch.reqs);
            batch.reqs = shared.admission.try_admit(n, reqs);
            if batch.reqs.is_empty() {
                return; // every request parked — answered after the load
            }
            // A rejected `requeued` request may still win: the loader
            // that re-enqueued it made the model resident — resolve once
            // more before failing.
            engine = shared.registry.get(n);
        }
    }
    shared.inflight.inc();
    let label = target.as_deref().unwrap_or("_none").to_string();
    let mh = hists.entry(label.clone()).or_insert_with(|| ModelHists::new(&shared.metrics, &label));
    mh.dispatch_wait
        .record(picked.saturating_duration_since(batch.formed).as_micros() as u64);
    // 1/N batch sampling decides whether this batch's spans are recorded
    // (tracing-off cost: one relaxed load inside on_batch_start). The
    // guard keeps runtime-side span sites active for this batch's window
    // and is dropped when the batch finishes.
    let batch_trace = trace::on_batch_start();
    let sampled = batch_trace.sampled();
    if sampled {
        trace::record_span(
            SpanKind::BatchForm,
            batch.started,
            batch.formed,
            0,
            mh.trace_id(&label),
            batch.len() as u64,
        );
        // Counter tracks bracket the batch: this sample shows the
        // rising edge (inflight just incremented), the one at the end
        // shows the fall.
        record_counters(shared, mh.trace_id(&label));
    }
    let form_ms = batch.form_ms();
    shared.hist_batch_form.record_ms(form_ms);
    shared.hist_batch_size.record(batch.len() as u64);
    mh.batch_size.record(batch.len() as u64);
    for req in batch.reqs {
        let qms = batch.formed.saturating_duration_since(req.enqueued).as_secs_f64() * 1e3;
        if sampled {
            trace::record_span(
                SpanKind::Queue,
                req.enqueued,
                batch.formed,
                0,
                mh.trace_id(&label),
                req.id,
            );
        }
        // Expired requests are dropped at dequeue: nobody is waiting
        // for the answer, so the kernels never run.
        if req.deadline.is_some_and(|d| Instant::now() > d) {
            mh.expired.inc();
            mh.failed.inc();
            shared.expired.fetch_add(1, Ordering::Relaxed);
            shared.failed.fetch_add(1, Ordering::Relaxed);
            respond_error(&shared.pending, &req, ServeError::DeadlineExceeded);
            continue;
        }
        let t = Instant::now();
        // Failures (wrong input shape, non-resident model) must reach
        // the caller as typed errors, not masquerade as results. Engines
        // collecting per-layer metrics (all registry-served ones)
        // additionally feed the per-kernel-kind step histograms.
        let (out, error, layers) = match &engine {
            Some(e) if e.collect_metrics => match e.run_with_metrics(&req.input) {
                Ok((out, m)) => (out, None, Some(m)),
                Err(e) => {
                    (admission::error_output(), Some(ServeError::Exec(e.to_string())), None)
                }
            },
            Some(e) => match e.run(&req.input) {
                Ok(out) => (out, None, None),
                Err(e) => {
                    (admission::error_output(), Some(ServeError::Exec(e.to_string())), None)
                }
            },
            None => (
                admission::error_output(),
                Some(match &target {
                    Some(n) => ServeError::ModelNotResident { model: n.clone() },
                    None => ServeError::NoDefaultModel,
                }),
                None,
            ),
        };
        let ems = t.elapsed().as_secs_f64() * 1e3;
        if sampled {
            trace::record_span(
                SpanKind::Dispatch,
                t,
                Instant::now(),
                0,
                mh.trace_id(&label),
                req.id,
            );
        }
        if let Some(m) = &layers {
            for l in &m.layers {
                mh.step(&shared.metrics, &label, l.kind).record(l.micros.round() as u64);
            }
            // Roofline gauges: join the plan's static cost table with
            // this run's measured per-step times. Gauges overwrite, so
            // the scrape carries the latest run's attainment.
            if let Some(e) = &engine {
                if let Ok(p) = crate::obs::prof::join(&e.plan().costs, m, &shared.machine) {
                    crate::obs::prof::set_roofline_gauges(&shared.metrics, &label, &p);
                }
            }
        }
        // End-to-end latency includes intra-batch wait (requests
        // dispatched later in the batch carry their true
        // time-to-response).
        let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
        if error.is_none() {
            // only successful runs feed the latency and throughput
            // summaries
            shared.hist_latency.record_ms(latency_ms);
            shared.hist_queue.record_ms(qms);
            shared.hist_exec.record_ms(ems);
            mh.latency.record_ms(latency_ms);
            mh.queue.record_ms(qms);
            mh.exec.record_ms(ems);
            mh.completed.inc();
            shared.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            mh.failed.inc();
            shared.failed.fetch_add(1, Ordering::Relaxed);
        }
        let respond_start = sampled.then(Instant::now);
        let tx = shared.pending.lock().unwrap().remove(&req.id);
        if let Some(tx) = tx {
            let _ = tx.send(InferResponse {
                id: req.id,
                output: out,
                queue_ms: qms,
                batch_ms: form_ms,
                exec_ms: ems,
                error,
            });
        }
        if let Some(start) = respond_start {
            trace::record_span(
                SpanKind::Respond,
                start,
                Instant::now(),
                0,
                mh.trace_id(&label),
                req.id,
            );
        }
    }
    shared.inflight.dec();
    if sampled {
        record_counters(shared, mh.trace_id(&label));
    }
}

/// Sample the process gauges into Chrome counter tracks (`"C"` events):
/// inflight batches, admission-parked requests, and resident workspace
/// arena bytes. Only called on sampled batches.
fn record_counters(shared: &LaneShared, model: u32) {
    trace::record_counter(trace::CTR_INFLIGHT, model, shared.inflight.get());
    trace::record_counter(
        trace::CTR_PENDING_ADMISSIONS,
        model,
        shared.admission.parked_total() as u64,
    );
    if let Some(pool) = &shared.arena {
        let s = pool.stats();
        trace::record_counter(
            trace::CTR_ARENA_BYTES,
            model,
            (s.arena_bytes * s.arenas_created) as u64,
        );
    }
}

/// Quota-governor loop: every tick, compare each SLO'd model's observed
/// p99 against its target and nudge the model's runtime quota by one
/// bucket — up while over target, down while under half the target.
///
/// The p99 is **windowed**, not cumulative: each model's latency
/// histogram is wrapped in a [`HistogramWindow`], which summarizes only
/// the samples that arrived since the governor's last adjustment
/// decision, so an early latency spike ages out of the estimate instead
/// of pinning p99 above target forever (which would make the narrowing
/// branch unreachable). A window thinner than `MIN_SAMPLES` keeps
/// accumulating across ticks, so an idle or trickle model's quota is
/// never churned on noise.
fn run_governor(
    stop: &AtomicBool,
    registry: &ModelRegistry,
    metrics: &Registry,
    slo: &[(String, f64)],
) {
    /// New samples a model's window must hold before the governor trusts
    /// its p99 estimate.
    const MIN_SAMPLES: u64 = 8;
    let width = registry.runtime().threads();
    let mut windows: Vec<(&str, f64, HistogramWindow, Arc<Counter>)> = slo
        .iter()
        .map(|(m, t)| {
            (
                m.as_str(),
                *t,
                HistogramWindow::new(
                    metrics.histogram("grim_request_latency_us", &[("model", m)]),
                ),
                metrics.counter("grim_quota_adjustments_total", &[("model", m)]),
            )
        })
        .collect();
    while !stop.load(Ordering::Relaxed) {
        // ~100 ms cadence, but responsive to shutdown.
        for _ in 0..5 {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        for (model, target_ms, window, adjustments) in windows.iter_mut() {
            if window.count() < MIN_SAMPLES {
                continue; // window too thin — keep accumulating
            }
            let p99_ms = window.quantile(0.99) * 1e-3;
            window.advance();
            let cur = registry.runtime().effective_threads(model);
            if p99_ms > *target_ms && cur < width {
                registry.set_quota(model, cur + 1);
                adjustments.inc();
            } else if p99_ms < 0.5 * *target_ms && cur > 1 {
                registry.set_quota(model, cur - 1);
                adjustments.inc();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::util::Rng;

    fn plan_for(kind: ModelKind, preset: Preset, seed: u64) -> crate::compiler::ExecutionPlan {
        let opts = InitOptions { rate: 4.0, block: [4, 16], seed };
        let m = build_model(kind, preset, opts);
        let w = random_weights(&m, opts);
        compile(&m, &w, CompileOptions::default()).unwrap()
    }

    fn small_server() -> Server {
        let plan = plan_for(ModelKind::Gru, Preset::TimitMini, 3);
        Server::start(Engine::new(plan, 2), ServerConfig::default())
    }

    #[test]
    fn serves_single_request() {
        let server = small_server();
        let mut rng = Rng::new(1);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let resp = server.infer(x).unwrap();
        assert_eq!(resp.output.numel(), 40);
        assert!(resp.exec_ms > 0.0);
    }

    #[test]
    fn serves_concurrent_requests_no_loss() {
        let server = Arc::new(small_server());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let resp = s.infer(x).unwrap();
                    assert_eq!(resp.output.numel(), 40);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.completed, 40);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency_ms.p99 >= stats.latency_ms.p50);
    }

    #[test]
    fn wrong_shape_surfaces_as_error() {
        let server = small_server();
        let mut rng = Rng::new(33);
        // model expects [20, 19]
        let bad = Tensor::rand_uniform(&[3, 3], 1.0, &mut rng);
        let err = server.infer(bad).unwrap_err();
        assert!(err.to_string().contains("inference failed"), "{err}");
        // server keeps serving valid requests afterwards
        let good = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer(good).unwrap().error.is_none());
        // failures are tracked separately and never skew the summaries
        let stats = server.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.latency_ms.count, 1);
    }

    #[test]
    fn serving_reuses_one_arena() {
        let server = small_server();
        // A single-model server defaults to one dispatcher lane — the
        // serial-dispatch guarantee the arena assertion depends on.
        assert_eq!(server.dispatch_lanes(), 1);
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.arena.checkouts, 6, "one arena checkout per request");
        assert_eq!(
            stats.arena.arenas_created, 1,
            "a single dispatcher lane must reuse one arena"
        );
        assert!(stats.arena.arena_bytes > 0);
    }

    #[test]
    fn shutdown_returns_stats() {
        let server = small_server();
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 3);
    }

    /// Explicit lane config wins over the resident-model default, and
    /// the zero floor holds.
    #[test]
    fn lane_config_resolution() {
        let plan = plan_for(ModelKind::Gru, Preset::TimitMini, 4);
        let server = Server::start(
            Engine::new(plan, 2),
            ServerConfig { max_inflight: Some(3), ..ServerConfig::default() },
        );
        if std::env::var("GRIM_SERIAL_DISPATCH").is_ok_and(|v| v == "1") {
            assert_eq!(server.dispatch_lanes(), 1, "env override forces serial dispatch");
        } else {
            assert_eq!(server.dispatch_lanes(), 3);
        }
        let mut rng = Rng::new(5);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer(x).is_ok());
    }

    /// Two models behind one server: routing by name, concurrent clients,
    /// no cross-talk, and per-model pool isolation.
    #[test]
    fn registry_server_routes_two_models_concurrently() {
        let registry = Arc::new(ModelRegistry::new(2));
        registry.insert_plan("cnn", plan_for(ModelKind::Vgg16, Preset::CifarMini, 5));
        registry.insert_plan("rnn", plan_for(ModelKind::Gru, Preset::TimitMini, 6));
        let server = Arc::new(Server::start_registry(Arc::clone(&registry), ServerConfig::default()));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t);
                for _ in 0..6 {
                    let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
                    let resp = s.infer_on("cnn", x).unwrap();
                    assert_eq!(resp.output.numel(), 10, "cnn output routed back");
                }
            }));
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(300 + t);
                for _ in 0..6 {
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let resp = s.infer_on("rnn", x).unwrap();
                    assert_eq!(resp.output.numel(), 40, "rnn output routed back");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().completed, 24);
        let stats = registry.stats();
        assert_eq!(stats.len(), 2);
        for ms in &stats {
            assert_eq!(
                ms.pool.checkouts, 12,
                "model '{}' must serve exactly its own 12 requests",
                ms.name
            );
        }
    }

    /// Unknown model names and missing defaults fail loudly, and the
    /// server keeps serving. (No artifact directory is configured, so
    /// admission control cannot park these — the classic typed-error
    /// path must be fully preserved.)
    #[test]
    fn unknown_model_is_an_error() {
        let registry = Arc::new(ModelRegistry::new(1));
        registry.insert_plan("rnn", plan_for(ModelKind::Gru, Preset::TimitMini, 7));
        let server = Server::start_registry(Arc::clone(&registry), ServerConfig::default());
        let mut rng = Rng::new(8);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let err = server.infer_on("nope", x.clone()).unwrap_err();
        assert!(err.to_string().contains("not resident"), "{err}");
        // The typed variant is observable on the raw response path, and
        // the per-model miss counter advanced.
        let resp = server.submit_to("nope", x.clone()).unwrap().recv().unwrap();
        assert_eq!(
            resp.error,
            Some(ServeError::ModelNotResident { model: "nope".to_string() })
        );
        assert_eq!(registry.not_resident("nope"), 2);
        // No default on a registry server: unnamed requests fail too.
        let err = server.infer(x.clone()).unwrap_err();
        assert!(err.to_string().contains("no default"), "{err}");
        assert!(server.infer_on("rnn", x).is_ok());
        let stats = server.stats();
        assert_eq!(stats.failed, 3);
        assert_eq!(stats.completed, 1);
    }

    /// Models hot-loaded (and evicted) while the server is running are
    /// picked up by the lanes' execution-time resolution.
    #[test]
    fn hot_load_and_evict_while_serving() {
        let registry = Arc::new(ModelRegistry::new(1));
        let server = Server::start_registry(Arc::clone(&registry), ServerConfig::default());
        let mut rng = Rng::new(9);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer_on("late", x.clone()).is_err(), "not loaded yet");
        registry.insert_plan("late", plan_for(ModelKind::Gru, Preset::TimitMini, 10));
        assert!(server.infer_on("late", x.clone()).is_ok(), "hot-loaded model serves");
        registry.evict("late");
        assert!(server.infer_on("late", x).is_err(), "evicted model fails loudly");
    }

    /// An already-expired deadline surfaces the typed error without
    /// executing, and the expired accounting advances.
    #[test]
    fn expired_deadline_is_dropped_at_dequeue() {
        let server = small_server();
        let mut rng = Rng::new(12);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let resp = server
            .submit_with_deadline(None, x.clone(), Duration::ZERO)
            .unwrap()
            .recv()
            .unwrap();
        assert_eq!(resp.error, Some(ServeError::DeadlineExceeded));
        assert_eq!(resp.exec_ms, 0.0, "expired requests must never execute");
        // A generous deadline still serves.
        let ok = server
            .submit_with_deadline(None, x, Duration::from_secs(60))
            .unwrap()
            .recv()
            .unwrap();
        assert!(ok.error.is_none());
        let stats = server.stats();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.failed, 1, "expired counts as failed");
        assert_eq!(stats.completed, 1);
        let prom = server.render_prometheus();
        assert!(prom.contains("grim_requests_expired_total"), "{prom}");
        assert!(prom.contains("grim_dispatch_lanes"), "{prom}");
    }
}
