//! The serving loop: a scheduler thread pulls batches and executes them on
//! the target engine; clients submit via a handle and receive responses
//! over per-request channels.
//!
//! Routing is by model name, threaded end to end through the coordinator:
//! every [`InferRequest`] names its target model (or `None` for the
//! server's default), the batcher forms model-homogeneous batches, and
//! the scheduler resolves each batch's name against a
//! [`ModelRegistry`] at execution time. A single-model
//! [`Server::start`] is just a registry of one with that model as the
//! default; [`Server::start_registry`] serves as many models as the
//! registry holds, each with its own isolated workspace pool — and the
//! registry stays shared, so models can be hot-loaded or evicted while
//! the server runs.

use super::batcher::{Batcher, BatchPolicy};
use super::queue::{InferRequest, InferResponse, RequestQueue, ServeError};
use crate::engine::Engine;
use crate::memory::{PoolStats, WorkspacePool};
use crate::obs::trace::{self, SpanKind};
use crate::obs::{Counter, Histogram, Registry};
use crate::serving::ModelRegistry;
use crate::tensor::Tensor;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_capacity: 256, batch: BatchPolicy::default() }
    }
}

/// Aggregated serving statistics. Summaries come from bounded
/// log₂-bucketed histograms ([`crate::obs::Histogram`]), not an
/// unbounded sample vector — count/mean/min/max are exact,
/// p50/p90/p99 are bucket estimates.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub batches: u64,
    /// End-to-end request latency (enqueue → response ready).
    pub latency_ms: Summary,
    /// Queue wait (enqueue → the request's batch formed).
    pub queue_ms: Summary,
    /// Engine execution time.
    pub exec_ms: Summary,
    /// Batch-formation window (one sample per batch).
    pub batch_form_ms: Summary,
    /// Batch-size distribution (one sample per batch, unitless).
    pub batch_size: Summary,
    pub throughput_rps: f64,
    /// Requests that failed execution (wrong shape, unknown model, plan
    /// errors). These are excluded from `completed` and from the
    /// latency/throughput summaries so a burst of fast failures cannot
    /// flatter the stats.
    pub failed: u64,
    /// Workspace-arena pool telemetry of the *default* model (zeroed for
    /// registry servers without one — use `ModelRegistry::stats` for the
    /// per-model breakdown).
    pub arena: PoolStats,
    /// Per-model end-to-end latency summaries (ms), sorted by model
    /// name; unnamed-default traffic appears under the default model's
    /// name.
    pub per_model: Vec<(String, Summary)>,
}

/// A running inference server over one or many compiled models.
pub struct Server {
    queue: Arc<RequestQueue>,
    next_id: AtomicU64,
    pending: Arc<Mutex<HashMap<u64, Sender<InferResponse>>>>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    /// Per-model labeled series (latency/queue/exec/batch/step
    /// histograms + completion counters) — the Prometheus surface.
    metrics: Arc<Registry>,
    /// Server-wide histograms, kept out of the registry so the labeled
    /// per-model families stay label-consistent in the text dump.
    hist_latency: Arc<Histogram>,
    hist_queue: Arc<Histogram>,
    hist_exec: Arc<Histogram>,
    hist_batch_form: Arc<Histogram>,
    hist_batch_size: Arc<Histogram>,
    started: Instant,
    completed: Arc<AtomicU64>,
    failed: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    /// The model registry requests are resolved against (shared: models
    /// can be hot-loaded/evicted while serving).
    registry: Arc<ModelRegistry>,
    /// Model served when a request names none ([`Self::start`] sets it).
    default_model: Option<String>,
    /// The default model's workspace pool, kept observable for stats.
    arena: Option<Arc<WorkspacePool>>,
}

/// Cached per-model metric handles: one registry-mutex hit per new
/// model (and per new kernel kind), pure atomics in steady state.
struct ModelHists {
    latency: Arc<Histogram>,
    queue: Arc<Histogram>,
    exec: Arc<Histogram>,
    batch_size: Arc<Histogram>,
    completed: Arc<Counter>,
    failed: Arc<Counter>,
    steps: HashMap<&'static str, Arc<Histogram>>,
    trace_id: u32,
}

impl ModelHists {
    fn new(reg: &Registry, model: &str) -> Self {
        let l: &[(&str, &str)] = &[("model", model)];
        ModelHists {
            latency: reg.histogram("grim_request_latency_us", l),
            queue: reg.histogram("grim_queue_wait_us", l),
            exec: reg.histogram("grim_exec_time_us", l),
            batch_size: reg.histogram("grim_batch_size", l),
            completed: reg.counter("grim_requests_completed_total", l),
            failed: reg.counter("grim_requests_failed_total", l),
            steps: HashMap::new(),
            trace_id: 0,
        }
    }

    /// Step-time histogram for one kernel kind, registered on first use.
    fn step(&mut self, reg: &Registry, model: &str, kind: &'static str) -> &Histogram {
        self.steps.entry(kind).or_insert_with(|| {
            reg.histogram("grim_step_time_us", &[("model", model), ("kind", kind)])
        })
    }

    /// Interned trace id of the model label, resolved on the first
    /// sampled batch (never on the tracing-off path).
    fn trace_id(&mut self, model: &str) -> u32 {
        if self.trace_id == 0 {
            self.trace_id = trace::intern(model);
        }
        self.trace_id
    }
}

impl Server {
    /// Start a single-model server: `engine` becomes the registry's sole
    /// entry and the default route.
    pub fn start(engine: Engine, config: ServerConfig) -> Self {
        let name = engine.plan().name.clone();
        // The one-model registry borrows the engine's runtime — no
        // second worker pool is spawned.
        let registry = Arc::new(ModelRegistry::with_runtime(engine.runtime(), usize::MAX));
        let arena = engine.workspace_pool();
        registry.insert_engine(name.clone(), engine);
        Self::start_inner(registry, Some(name), Some(arena), config)
    }

    /// Start a multi-model server over a shared registry. Requests must
    /// name their model ([`Self::submit_to`] / [`Self::infer_on`]).
    pub fn start_registry(registry: Arc<ModelRegistry>, config: ServerConfig) -> Self {
        Self::start_inner(registry, None, None, config)
    }

    fn start_inner(
        registry: Arc<ModelRegistry>,
        default_model: Option<String>,
        arena: Option<Arc<WorkspacePool>>,
        config: ServerConfig,
    ) -> Self {
        let queue = Arc::new(RequestQueue::new(config.queue_capacity));
        let pending: Arc<Mutex<HashMap<u64, Sender<InferResponse>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(Registry::new());
        let hist_latency = Arc::new(Histogram::new());
        let hist_queue = Arc::new(Histogram::new());
        let hist_exec = Arc::new(Histogram::new());
        let hist_batch_form = Arc::new(Histogram::new());
        let hist_batch_size = Arc::new(Histogram::new());
        let completed = Arc::new(AtomicU64::new(0));
        let failed = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));

        let q2 = Arc::clone(&queue);
        let p2 = Arc::clone(&pending);
        let m2 = Arc::clone(&metrics);
        let h_lat = Arc::clone(&hist_latency);
        let h_q = Arc::clone(&hist_queue);
        let h_ex = Arc::clone(&hist_exec);
        let h_bf = Arc::clone(&hist_batch_form);
        let h_bs = Arc::clone(&hist_batch_size);
        let c2 = Arc::clone(&completed);
        let f2 = Arc::clone(&failed);
        let b2 = Arc::clone(&batches);
        let reg = Arc::clone(&registry);
        let default = default_model.clone();
        let policy = config.batch;
        let scheduler = std::thread::Builder::new()
            .name("grim-scheduler".into())
            .spawn(move || {
                // Per-model batching: the registry's policy overrides
                // win over the server-wide default, resolved per batch
                // head (unnamed requests resolve through the default
                // model's name).
                let preg = Arc::clone(&reg);
                let pdefault = default.clone();
                let batcher = Batcher::with_policy_resolver(
                    &q2,
                    policy,
                    Box::new(move |m| {
                        let name = m.or(pdefault.as_deref())?;
                        preg.policy_for(name)
                    }),
                );
                // Per-model metric handles, cached so the steady state
                // never touches the registry mutex.
                let mut hists: HashMap<String, ModelHists> = HashMap::new();
                while let Some(batch) = batcher.next_batch() {
                    b2.fetch_add(1, Ordering::Relaxed);
                    // Batches are model-homogeneous; resolve once per
                    // batch, at execution time — a model evicted while
                    // its requests sat in the queue fails them loudly
                    // instead of silently pinning its memory.
                    let target = batch.reqs[0].model.clone().or_else(|| default.clone());
                    let engine = target.as_deref().and_then(|n| reg.get(n));
                    if let (None, Some(n)) = (&engine, &target) {
                        // One miss per failed request (batched: one
                        // lock); the counter is the admission-control
                        // signal.
                        reg.note_misses(n, batch.len() as u64);
                    }
                    let label = target.as_deref().unwrap_or("_none").to_string();
                    let mh = hists
                        .entry(label.clone())
                        .or_insert_with(|| ModelHists::new(&m2, &label));
                    // 1/N batch sampling decides whether this batch's
                    // spans are recorded (tracing-off cost: one relaxed
                    // load inside on_batch_start).
                    let sampled = trace::on_batch_start();
                    if sampled {
                        trace::record_span(
                            SpanKind::BatchForm,
                            batch.started,
                            batch.formed,
                            0,
                            mh.trace_id(&label),
                            batch.len() as u64,
                        );
                    }
                    let form_ms = batch.form_ms();
                    h_bf.record_ms(form_ms);
                    h_bs.record(batch.len() as u64);
                    mh.batch_size.record(batch.len() as u64);
                    for req in batch.reqs {
                        let qms = batch
                            .formed
                            .saturating_duration_since(req.enqueued)
                            .as_secs_f64()
                            * 1e3;
                        if sampled {
                            trace::record_span(
                                SpanKind::Queue,
                                req.enqueued,
                                batch.formed,
                                0,
                                mh.trace_id(&label),
                                req.id,
                            );
                        }
                        let t = Instant::now();
                        // Failures (wrong input shape, non-resident
                        // model) must reach the caller as typed errors,
                        // not masquerade as results. Engines collecting
                        // per-layer metrics (all registry-served ones)
                        // additionally feed the per-kernel-kind step
                        // histograms.
                        let (out, error, layers) = match &engine {
                            Some(e) if e.collect_metrics => {
                                match e.run_with_metrics(&req.input) {
                                    Ok((out, m)) => (out, None, Some(m)),
                                    Err(e) => (
                                        Tensor::zeros(&[1]),
                                        Some(ServeError::Exec(e.to_string())),
                                        None,
                                    ),
                                }
                            }
                            Some(e) => match e.run(&req.input) {
                                Ok(out) => (out, None, None),
                                Err(e) => (
                                    Tensor::zeros(&[1]),
                                    Some(ServeError::Exec(e.to_string())),
                                    None,
                                ),
                            },
                            None => (
                                Tensor::zeros(&[1]),
                                Some(match &target {
                                    Some(n) => {
                                        ServeError::ModelNotResident { model: n.clone() }
                                    }
                                    None => ServeError::NoDefaultModel,
                                }),
                                None,
                            ),
                        };
                        let ems = t.elapsed().as_secs_f64() * 1e3;
                        if sampled {
                            trace::record_span(
                                SpanKind::Dispatch,
                                t,
                                Instant::now(),
                                0,
                                mh.trace_id(&label),
                                req.id,
                            );
                        }
                        if let Some(m) = &layers {
                            for l in &m.layers {
                                mh.step(&m2, &label, l.kind).record(l.micros.round() as u64);
                            }
                        }
                        // End-to-end latency includes intra-batch wait
                        // (requests dispatched later in the batch carry
                        // their true time-to-response).
                        let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                        if error.is_none() {
                            // only successful runs feed the latency and
                            // throughput summaries
                            h_lat.record_ms(latency_ms);
                            h_q.record_ms(qms);
                            h_ex.record_ms(ems);
                            mh.latency.record_ms(latency_ms);
                            mh.queue.record_ms(qms);
                            mh.exec.record_ms(ems);
                            mh.completed.inc();
                            c2.fetch_add(1, Ordering::Relaxed);
                        } else {
                            mh.failed.inc();
                            f2.fetch_add(1, Ordering::Relaxed);
                        }
                        let respond_start = sampled.then(Instant::now);
                        let tx = p2.lock().unwrap().remove(&req.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(InferResponse {
                                id: req.id,
                                output: out,
                                queue_ms: qms,
                                batch_ms: form_ms,
                                exec_ms: ems,
                                error,
                            });
                        }
                        if let Some(start) = respond_start {
                            trace::record_span(
                                SpanKind::Respond,
                                start,
                                Instant::now(),
                                0,
                                mh.trace_id(&label),
                                req.id,
                            );
                        }
                    }
                }
            })
            .expect("spawn scheduler");

        Server {
            queue,
            next_id: AtomicU64::new(1),
            pending,
            scheduler: Some(scheduler),
            metrics,
            hist_latency,
            hist_queue,
            hist_exec,
            hist_batch_form,
            hist_batch_size,
            started: Instant::now(),
            completed,
            failed,
            batches,
            registry,
            default_model,
            arena,
        }
    }

    /// The registry this server routes over (hot-load models through it).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    fn enqueue(
        &self,
        model: Option<String>,
        input: Tensor,
    ) -> anyhow::Result<Receiver<InferResponse>> {
        // Normalize an explicit request for the default model to `None`
        // so it batches with unnamed requests (the batcher groups by the
        // literal model field; without this, mixing submit() and
        // submit_to(default) would fragment every batch).
        let model = match (&self.default_model, model) {
            (Some(d), Some(m)) if *d == m => None,
            (_, m) => m,
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(id, tx);
        self.queue
            .push(InferRequest { id, model, input, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server closed"))?;
        Ok(rx)
    }

    /// Submit a request to the default model; returns a receiver for the
    /// response. Blocks (backpressure) when the queue is full.
    pub fn submit(&self, input: Tensor) -> anyhow::Result<Receiver<InferResponse>> {
        self.enqueue(None, input)
    }

    /// Submit a request routed to the named model.
    pub fn submit_to(&self, model: &str, input: Tensor) -> anyhow::Result<Receiver<InferResponse>> {
        self.enqueue(Some(model.to_string()), input)
    }

    /// Submit and wait for the response (convenience). Execution
    /// failures surface as `Err`, never as a placeholder output.
    pub fn infer(&self, input: Tensor) -> anyhow::Result<InferResponse> {
        Self::wait(self.submit(input)?)
    }

    /// Submit to the named model and wait for the response.
    pub fn infer_on(&self, model: &str, input: Tensor) -> anyhow::Result<InferResponse> {
        Self::wait(self.submit_to(model, input)?)
    }

    fn wait(rx: Receiver<InferResponse>) -> anyhow::Result<InferResponse> {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?;
        if let Some(e) = &resp.error {
            anyhow::bail!("inference failed: {e}");
        }
        Ok(resp)
    }

    /// Current stats snapshot.
    pub fn stats(&self) -> ServerStats {
        let completed = self.completed.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut per_model: Vec<(String, Summary)> = self
            .metrics
            .histograms_named("grim_request_latency_us")
            .into_iter()
            .map(|(labels, h)| {
                let name = labels
                    .iter()
                    .find(|(k, _)| k == "model")
                    .map(|(_, v)| v.clone())
                    .unwrap_or_default();
                (name, h.summary(1e-3))
            })
            .collect();
        per_model.sort_by(|a, b| a.0.cmp(&b.0));
        ServerStats {
            completed,
            batches: self.batches.load(Ordering::Relaxed),
            latency_ms: self.hist_latency.summary(1e-3),
            queue_ms: self.hist_queue.summary(1e-3),
            exec_ms: self.hist_exec.summary(1e-3),
            batch_form_ms: self.hist_batch_form.summary(1e-3),
            batch_size: self.hist_batch_size.summary(1.0),
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            failed: self.failed.load(Ordering::Relaxed),
            arena: self.arena.as_ref().map(|a| a.stats()).unwrap_or_default(),
            per_model,
        }
    }

    /// The server's metric registry (per-model labeled series).
    pub fn metrics(&self) -> Arc<Registry> {
        Arc::clone(&self.metrics)
    }

    /// Render the full metrics surface in Prometheus text exposition
    /// format: per-model labeled series from the registry, server-level
    /// counters/uptime, and the model registry's resident/arena/quota
    /// gauges. `grim serve --stats-out` writes this; `grim stats`
    /// parses it back.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = self.metrics.render();
        let _ = writeln!(out, "# TYPE grim_server_requests_completed_total counter");
        let _ = writeln!(
            out,
            "grim_server_requests_completed_total {}",
            self.completed.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_requests_failed_total counter");
        let _ = writeln!(
            out,
            "grim_server_requests_failed_total {}",
            self.failed.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_batches_total counter");
        let _ = writeln!(
            out,
            "grim_server_batches_total {}",
            self.batches.load(Ordering::Relaxed)
        );
        let _ = writeln!(out, "# TYPE grim_server_uptime_seconds gauge");
        let _ = writeln!(
            out,
            "grim_server_uptime_seconds {:.3}",
            self.started.elapsed().as_secs_f64()
        );
        self.registry.render_prometheus_into(&mut out);
        out
    }

    /// Stop accepting requests, drain, and join the scheduler.
    pub fn shutdown(mut self) -> ServerStats {
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        self.stats()
    }

    /// The default model's name, when this server has one.
    pub fn default_model(&self) -> Option<&str> {
        self.default_model.as_deref()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::util::Rng;

    fn plan_for(kind: ModelKind, preset: Preset, seed: u64) -> crate::compiler::ExecutionPlan {
        let opts = InitOptions { rate: 4.0, block: [4, 16], seed };
        let m = build_model(kind, preset, opts);
        let w = random_weights(&m, opts);
        compile(&m, &w, CompileOptions::default()).unwrap()
    }

    fn small_server() -> Server {
        let plan = plan_for(ModelKind::Gru, Preset::TimitMini, 3);
        Server::start(Engine::new(plan, 2), ServerConfig::default())
    }

    #[test]
    fn serves_single_request() {
        let server = small_server();
        let mut rng = Rng::new(1);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let resp = server.infer(x).unwrap();
        assert_eq!(resp.output.numel(), 40);
        assert!(resp.exec_ms > 0.0);
    }

    #[test]
    fn serves_concurrent_requests_no_loss() {
        let server = Arc::new(small_server());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let resp = s.infer(x).unwrap();
                    assert_eq!(resp.output.numel(), 40);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.completed, 40);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency_ms.p99 >= stats.latency_ms.p50);
    }

    #[test]
    fn wrong_shape_surfaces_as_error() {
        let server = small_server();
        let mut rng = Rng::new(33);
        // model expects [20, 19]
        let bad = Tensor::rand_uniform(&[3, 3], 1.0, &mut rng);
        let err = server.infer(bad).unwrap_err();
        assert!(err.to_string().contains("inference failed"), "{err}");
        // server keeps serving valid requests afterwards
        let good = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer(good).unwrap().error.is_none());
        // failures are tracked separately and never skew the summaries
        let stats = server.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.latency_ms.count, 1);
    }

    #[test]
    fn serving_reuses_one_arena() {
        let server = small_server();
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.arena.checkouts, 6, "one arena checkout per request");
        assert_eq!(
            stats.arena.arenas_created, 1,
            "the single scheduler thread must reuse one arena"
        );
        assert!(stats.arena.arena_bytes > 0);
    }

    #[test]
    fn shutdown_returns_stats() {
        let server = small_server();
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 3);
    }

    /// Two models behind one server: routing by name, concurrent clients,
    /// no cross-talk, and per-model pool isolation.
    #[test]
    fn registry_server_routes_two_models_concurrently() {
        let registry = Arc::new(ModelRegistry::new(2));
        registry.insert_plan("cnn", plan_for(ModelKind::Vgg16, Preset::CifarMini, 5));
        registry.insert_plan("rnn", plan_for(ModelKind::Gru, Preset::TimitMini, 6));
        let server = Arc::new(Server::start_registry(Arc::clone(&registry), ServerConfig::default()));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(200 + t);
                for _ in 0..6 {
                    let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
                    let resp = s.infer_on("cnn", x).unwrap();
                    assert_eq!(resp.output.numel(), 10, "cnn output routed back");
                }
            }));
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(300 + t);
                for _ in 0..6 {
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let resp = s.infer_on("rnn", x).unwrap();
                    assert_eq!(resp.output.numel(), 40, "rnn output routed back");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().completed, 24);
        let stats = registry.stats();
        assert_eq!(stats.len(), 2);
        for ms in &stats {
            assert_eq!(
                ms.pool.checkouts, 12,
                "model '{}' must serve exactly its own 12 requests",
                ms.name
            );
        }
    }

    /// Unknown model names and missing defaults fail loudly, and the
    /// server keeps serving.
    #[test]
    fn unknown_model_is_an_error() {
        let registry = Arc::new(ModelRegistry::new(1));
        registry.insert_plan("rnn", plan_for(ModelKind::Gru, Preset::TimitMini, 7));
        let server = Server::start_registry(Arc::clone(&registry), ServerConfig::default());
        let mut rng = Rng::new(8);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let err = server.infer_on("nope", x.clone()).unwrap_err();
        assert!(err.to_string().contains("not resident"), "{err}");
        // The typed variant is observable on the raw response path, and
        // the per-model miss counter advanced.
        let resp = server.submit_to("nope", x.clone()).unwrap().recv().unwrap();
        assert_eq!(
            resp.error,
            Some(ServeError::ModelNotResident { model: "nope".to_string() })
        );
        assert_eq!(registry.not_resident("nope"), 2);
        // No default on a registry server: unnamed requests fail too.
        let err = server.infer(x.clone()).unwrap_err();
        assert!(err.to_string().contains("no default"), "{err}");
        assert!(server.infer_on("rnn", x).is_ok());
        let stats = server.stats();
        assert_eq!(stats.failed, 3);
        assert_eq!(stats.completed, 1);
    }

    /// Models hot-loaded (and evicted) while the server is running are
    /// picked up by the scheduler's execution-time resolution.
    #[test]
    fn hot_load_and_evict_while_serving() {
        let registry = Arc::new(ModelRegistry::new(1));
        let server = Server::start_registry(Arc::clone(&registry), ServerConfig::default());
        let mut rng = Rng::new(9);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer_on("late", x.clone()).is_err(), "not loaded yet");
        registry.insert_plan("late", plan_for(ModelKind::Gru, Preset::TimitMini, 10));
        assert!(server.infer_on("late", x.clone()).is_ok(), "hot-loaded model serves");
        registry.evict("late");
        assert!(server.infer_on("late", x).is_err(), "evicted model fails loudly");
    }
}
