//! The serving loop: a scheduler thread pulls batches and executes them on
//! the engine; clients submit via a handle and receive responses over
//! per-request channels.

use super::batcher::{Batcher, BatchPolicy};
use super::queue::{InferRequest, InferResponse, RequestQueue};
use crate::engine::Engine;
use crate::memory::{PoolStats, WorkspacePool};
use crate::tensor::Tensor;
use crate::util::stats::{summarize, Summary};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_capacity: 256, batch: BatchPolicy::default() }
    }
}

/// Aggregated serving statistics.
#[derive(Clone, Debug)]
pub struct ServerStats {
    pub completed: u64,
    pub batches: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exec_ms: Summary,
    pub throughput_rps: f64,
    /// Requests that failed execution (wrong shape, plan errors). These
    /// are excluded from `completed` and from the latency/throughput
    /// summaries so a burst of fast failures cannot flatter the stats.
    pub failed: u64,
    /// Workspace-arena pool telemetry: arena size, arenas ever created
    /// (peak concurrency), checkouts (one per inference) — the zero-alloc
    /// evidence for the serving path.
    pub arena: PoolStats,
}

/// A running inference server over one compiled model.
pub struct Server {
    queue: Arc<RequestQueue>,
    next_id: AtomicU64,
    pending: Arc<Mutex<HashMap<u64, Sender<InferResponse>>>>,
    scheduler: Option<std::thread::JoinHandle<()>>,
    samples: Arc<Mutex<Vec<(f64, f64)>>>, // (queue_ms, exec_ms)
    started: Instant,
    completed: Arc<AtomicU64>,
    failed: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    /// The engine's workspace pool, shared so stats stay observable after
    /// the engine moves into the scheduler thread.
    arena: Arc<WorkspacePool>,
}

impl Server {
    /// Start the scheduler thread over `engine`.
    pub fn start(engine: Engine, config: ServerConfig) -> Self {
        let queue = Arc::new(RequestQueue::new(config.queue_capacity));
        let pending: Arc<Mutex<HashMap<u64, Sender<InferResponse>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let samples = Arc::new(Mutex::new(Vec::new()));
        let completed = Arc::new(AtomicU64::new(0));
        let failed = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));

        let q2 = Arc::clone(&queue);
        let p2 = Arc::clone(&pending);
        let s2 = Arc::clone(&samples);
        let c2 = Arc::clone(&completed);
        let f2 = Arc::clone(&failed);
        let b2 = Arc::clone(&batches);
        let arena = engine.workspace_pool();
        let policy = config.batch;
        let scheduler = std::thread::Builder::new()
            .name("grim-scheduler".into())
            .spawn(move || {
                let batcher = Batcher::new(&q2, policy);
                while let Some(batch) = batcher.next_batch() {
                    b2.fetch_add(1, Ordering::Relaxed);
                    for req in batch {
                        let qms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                        let t = Instant::now();
                        // Failures (wrong input shape, plan errors) must
                        // reach the caller, not masquerade as results.
                        let (out, error) = match engine.run(&req.input) {
                            Ok(out) => (out, None),
                            Err(e) => (Tensor::zeros(&[1]), Some(e.to_string())),
                        };
                        let ems = t.elapsed().as_secs_f64() * 1e3;
                        if error.is_none() {
                            // only successful runs feed the latency and
                            // throughput summaries
                            s2.lock().unwrap().push((qms, ems));
                            c2.fetch_add(1, Ordering::Relaxed);
                        } else {
                            f2.fetch_add(1, Ordering::Relaxed);
                        }
                        let tx = p2.lock().unwrap().remove(&req.id);
                        if let Some(tx) = tx {
                            let _ = tx.send(InferResponse {
                                id: req.id,
                                output: out,
                                queue_ms: qms,
                                exec_ms: ems,
                                error,
                            });
                        }
                    }
                }
            })
            .expect("spawn scheduler");

        Server {
            queue,
            next_id: AtomicU64::new(1),
            pending,
            scheduler: Some(scheduler),
            samples,
            started: Instant::now(),
            completed,
            failed,
            batches,
            arena,
        }
    }

    /// Submit a request; returns a receiver for the response.
    /// Blocks (backpressure) when the queue is full.
    pub fn submit(&self, input: Tensor) -> anyhow::Result<std::sync::mpsc::Receiver<InferResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(id, tx);
        self.queue
            .push(InferRequest { id, input, enqueued: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server closed"))?;
        Ok(rx)
    }

    /// Submit and wait for the response (convenience). Execution
    /// failures surface as `Err`, never as a placeholder output.
    pub fn infer(&self, input: Tensor) -> anyhow::Result<InferResponse> {
        let rx = self.submit(input)?;
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?;
        if let Some(e) = &resp.error {
            anyhow::bail!("inference failed: {e}");
        }
        Ok(resp)
    }

    /// Current stats snapshot.
    pub fn stats(&self) -> ServerStats {
        let samples = self.samples.lock().unwrap();
        let queue_ms: Vec<f64> = samples.iter().map(|(q, _)| *q).collect();
        let exec_ms: Vec<f64> = samples.iter().map(|(_, e)| *e).collect();
        let total: Vec<f64> = samples.iter().map(|(q, e)| q + e).collect();
        let completed = self.completed.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        ServerStats {
            completed,
            batches: self.batches.load(Ordering::Relaxed),
            latency_ms: summarize(&total),
            queue_ms: summarize(&queue_ms),
            exec_ms: summarize(&exec_ms),
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            failed: self.failed.load(Ordering::Relaxed),
            arena: self.arena.stats(),
        }
    }

    /// Stop accepting requests, drain, and join the scheduler.
    pub fn shutdown(mut self) -> ServerStats {
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        self.stats()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::util::Rng;

    fn small_server() -> Server {
        let opts = InitOptions { rate: 4.0, block: [4, 16], seed: 3 };
        let m = build_model(ModelKind::Gru, Preset::TimitMini, opts);
        let w = random_weights(&m, opts);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        Server::start(Engine::new(plan, 2), ServerConfig::default())
    }

    #[test]
    fn serves_single_request() {
        let server = small_server();
        let mut rng = Rng::new(1);
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let resp = server.infer(x).unwrap();
        assert_eq!(resp.output.numel(), 40);
        assert!(resp.exec_ms > 0.0);
    }

    #[test]
    fn serves_concurrent_requests_no_loss() {
        let server = Arc::new(small_server());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&server);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..10 {
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let resp = s.infer(x).unwrap();
                    assert_eq!(resp.output.numel(), 40);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.completed, 40);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency_ms.p99 >= stats.latency_ms.p50);
    }

    #[test]
    fn wrong_shape_surfaces_as_error() {
        let server = small_server();
        let mut rng = Rng::new(33);
        // model expects [20, 19]
        let bad = Tensor::rand_uniform(&[3, 3], 1.0, &mut rng);
        let err = server.infer(bad).unwrap_err();
        assert!(err.to_string().contains("inference failed"), "{err}");
        // server keeps serving valid requests afterwards
        let good = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        assert!(server.infer(good).unwrap().error.is_none());
        // failures are tracked separately and never skew the summaries
        let stats = server.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.latency_ms.count, 1);
    }

    #[test]
    fn serving_reuses_one_arena() {
        let server = small_server();
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.arena.checkouts, 6, "one arena checkout per request");
        assert_eq!(
            stats.arena.arenas_created, 1,
            "the single scheduler thread must reuse one arena"
        );
        assert!(stats.arena.arena_bytes > 0);
    }

    #[test]
    fn shutdown_returns_stats() {
        let server = small_server();
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
            server.infer(x).unwrap();
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 3);
    }
}
