//! Dynamic batching policy: wait for the first request, then gather up to
//! `max_batch` more within `max_wait`. For CNN plans the engine executes
//! per-sample (batch = loop), but batching still amortizes dispatch and
//! keeps all pool workers busy; for GRU GEMV workloads batching converts
//! matrix-vector into matrix-matrix, which is where the paper's 81 µs @
//! batch 32 number comes from.

use super::queue::{InferRequest, RequestQueue};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// One formed batch with its formation window: `started` is when the
/// batcher picked up the first request, `formed` when it stopped
/// gathering — the difference is the batch-form latency reported in
/// [`super::queue::InferResponse::batch_ms`] and traced as the
/// `batch-form` span.
#[derive(Debug)]
pub struct Batch {
    pub reqs: Vec<InferRequest>,
    pub started: Instant,
    pub formed: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Batch-formation window in milliseconds.
    pub fn form_ms(&self) -> f64 {
        self.formed.saturating_duration_since(self.started).as_secs_f64() * 1e3
    }
}

/// Resolves the batching policy for a batch's target model. Registry
/// servers install one backed by per-model policy overrides; `None`
/// from the resolver falls back to the batcher's default policy.
pub type PolicyResolver<'a> = Box<dyn Fn(Option<&str>) -> Option<BatchPolicy> + Send + 'a>;

/// Pulls batches from a queue according to a policy — either one fixed
/// default, or a per-model override resolved per batch head.
pub struct Batcher<'a> {
    queue: &'a RequestQueue,
    policy: BatchPolicy,
    resolver: Option<PolicyResolver<'a>>,
}

impl<'a> Batcher<'a> {
    pub fn new(queue: &'a RequestQueue, policy: BatchPolicy) -> Self {
        Batcher { queue, policy, resolver: None }
    }

    /// Batcher whose policy is resolved per batch from the head
    /// request's target model (falling back to `default` when the
    /// resolver returns `None`) — a latency-sensitive RNN and a
    /// throughput CNN behind one server get different knobs.
    pub fn with_policy_resolver(
        queue: &'a RequestQueue,
        default: BatchPolicy,
        resolver: PolicyResolver<'a>,
    ) -> Self {
        Batcher { queue, policy: default, resolver: Some(resolver) }
    }

    /// The policy governing a batch headed by a request for `model`.
    fn policy_for(&self, model: &Option<String>) -> BatchPolicy {
        self.resolver
            .as_ref()
            .and_then(|r| r(model.as_deref()))
            .unwrap_or(self.policy)
    }

    /// Block for the next batch; None when the queue is closed and empty.
    ///
    /// Batches are homogeneous in target model: the first request fixes
    /// the model (and, via the resolver, the policy), further requests
    /// are gathered only while they match. A head-of-line request for a
    /// *different* model ships the batch immediately (no point waiting
    /// out the deadline — the batch cannot grow past it without
    /// reordering), and that request seeds the next batch.
    pub fn next_batch(&self) -> Option<Batch> {
        let first = self.queue.pop()?;
        let started = Instant::now();
        let model = first.model.clone();
        let policy = self.policy_for(&model);
        let mut batch = vec![first];
        let deadline = started + policy.max_wait;
        while batch.len() < policy.max_batch {
            let more = self
                .queue
                .drain_while_matching(policy.max_batch - batch.len(), &model);
            if !more.is_empty() {
                batch.extend(more);
                continue;
            }
            if self.queue.front_matches(&model) == Some(false) {
                break;
            }
            if Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        Some(Batch { reqs: batch, started, formed: Instant::now() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::sync::Arc;

    fn req(id: u64) -> InferRequest {
        InferRequest {
            id,
            model: None,
            input: Tensor::zeros(&[1]),
            enqueued: Instant::now(),
            deadline: None,
            requeued: false,
        }
    }

    fn req_for(id: u64, model: &str) -> InferRequest {
        InferRequest { model: Some(model.to_string()), ..req(id) }
    }

    /// Batches never mix models, preserve FIFO order, and a head-of-line
    /// request for another model ships the current batch early.
    #[test]
    fn batches_are_homogeneous_per_model() {
        let q = RequestQueue::new(16);
        for (id, m) in [(0, "a"), (1, "a"), (2, "b"), (3, "b"), (4, "a")] {
            q.push(req_for(id, m)).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) });
        let t = Instant::now();
        let first = b.next_batch().unwrap();
        assert_eq!(first.reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(first.reqs.iter().all(|r| r.model.as_deref() == Some("a")));
        assert!(first.formed >= first.started, "formation window must be well-ordered");
        assert!(
            t.elapsed() < Duration::from_millis(40),
            "a mismatched head must ship the batch before the deadline"
        );
        let second = b.next_batch().unwrap();
        assert_eq!(second.reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        let third = b.next_batch().unwrap();
        assert_eq!(third.reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![4]);
    }

    #[test]
    fn gathers_waiting_requests() {
        let q = RequestQueue::new(16);
        for i in 0..5 {
            q.push(req(i)).unwrap();
        }
        let b = Batcher::new(&q, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) });
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.reqs[0].id, 0);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 1);
    }

    #[test]
    fn respects_deadline_when_queue_empty() {
        let q = RequestQueue::new(16);
        q.push(req(0)).unwrap();
        let b = Batcher::new(&q, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) });
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() >= Duration::from_millis(2));
        assert!(batch.form_ms() >= 2.0, "the deadline wait is the formation window");
    }

    #[test]
    fn none_after_close() {
        let q = RequestQueue::new(4);
        q.close();
        let b = Batcher::new(&q, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    /// A per-model policy override caps one model's batches while the
    /// default still governs the other.
    #[test]
    fn per_model_policy_overrides_batch_size() {
        let q = RequestQueue::new(16);
        for (id, m) in [(0, "rt"), (1, "rt"), (2, "rt"), (3, "bulk"), (4, "bulk"), (5, "bulk")] {
            q.push(req_for(id, m)).unwrap();
        }
        let default = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let b = Batcher::with_policy_resolver(
            &q,
            default,
            Box::new(|m| match m {
                // latency-sensitive model: no batching at all
                Some("rt") => {
                    Some(BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(0) })
                }
                _ => None,
            }),
        );
        assert_eq!(b.next_batch().unwrap().reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.next_batch().unwrap().reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(b.next_batch().unwrap().reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        // the bulk model batches under the default policy
        assert_eq!(
            b.next_batch().unwrap().reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![3, 4, 5]
        );
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        let q = Arc::new(RequestQueue::new(64));
        let total = 200u64;
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..total {
                q2.push(req(i)).unwrap();
            }
            q2.close();
        });
        let b = Batcher::new(&q, BatchPolicy { max_batch: 7, max_wait: Duration::from_micros(200) });
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            seen.extend(batch.reqs.iter().map(|r| r.id));
        }
        producer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..total).collect::<Vec<_>>());
    }
}
