//! `grim` — the CLI leader binary.
//!
//! Subcommands:
//!   compile   AOT-compile a model into a .grimc artifact (encode+pack+plan offline)
//!   serve     start the inference server on a model — or, with
//!             --models <dir>, a multi-model registry of .grimc artifacts
//!   run       single inference on a model (random, .grim, or .grimc)
//!   inspect   compile a model and print its execution plan
//!   tune      auto-tune a model's layers (GA), print chosen configs
//!   blockopt  run the Listing-1 block-size optimizer for a layer shape
//!   xla       load + execute an AOT HLO artifact (jax bridge smoke test)
//!   export    build a model with random BCR weights and save a .grim
//!   profile   per-layer roofline attribution for a .grimc artifact
//!   bench-diff  compare two bench reports, exit 1 on regression
//!
//! No clap in the vendored dep set — a hand-rolled flag parser keeps the
//! surface small.

use grim::compiler::passes::{compile, Backend, CompileOptions};
use grim::coordinator::{BatchPolicy, HttpServer, Server, ServerConfig};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::runtime::ArtifactStore;
use grim::tensor::Tensor;
use grim::util::json::Json;
use grim::util::Rng;
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "compile" => cmd_compile(&flags),
        "serve" => cmd_serve(&flags),
        "run" => cmd_run(&flags),
        "inspect" => cmd_inspect(&flags),
        "tune" => cmd_tune(&flags),
        "blockopt" => cmd_blockopt(&flags),
        "xla" => cmd_xla(&flags),
        "export" => cmd_export(&flags),
        "report" => cmd_report(&flags),
        "stats" => cmd_stats(&flags),
        "profile" => cmd_profile(&args[1..], &flags),
        "bench-diff" => cmd_bench_diff(&args[1..], &flags),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "grim — BCR-sparse real-time DNN inference (paper reproduction)

USAGE: grim <command> [--flag value ...]

COMMANDS:
  compile  --model vgg16 --preset cifar-mini --rate 8 -o vgg.grimc [--cache generic|native] [--dtype f32|i8]
           AOT-compile to a .grimc artifact (cache blocking for the generic mobile target by default);
           --dtype i8 post-training-quantizes every packed BCRC layer (i8 codes, s32 accumulation,
           fused requantize epilogue at serve time)
  serve    --model vgg16 --preset cifar-mini --rate 8 --threads 8 --requests 64 --batch 8
  serve    --models dir/ [--budget-mb 256] [--threads 8] [--quota m=2,m2=4] [--batch-for m=1] --requests 64
           multi-model registry of .grimc files on ONE shared runtime (per-model quotas + batch policies)
           both serve forms accept [--trace out.json] [--trace-sample N] (Chrome/Perfetto span trace,
           1 batch in N sampled) and [--stats-out out.prom] (Prometheus text metrics dump)
           concurrency: [--max-inflight-batches N] dispatcher lanes (default: resident models,
           clamped to --threads; GRIM_SERIAL_DISPATCH=1 forces 1), [--slo-ms m=N] p99 latency
           targets driving dynamic per-model quotas, [--pending-cap N] admission-parked bound,
           [--http addr:port] JSON ingress (GET /healthz /metrics /stats, POST /v1/infer),
           [--duration secs] keep serving (e.g. for curl) before exiting
  run      --model resnet18 --preset cifar-mini --rate 8 [--grim-file m.grim] [--grimc-file m.grimc] [--backend grim|naive|opt|csr]
  inspect  --model vgg16 --preset cifar-mini --rate 8
  tune     --model vgg16 --preset cifar-mini --rate 8 [--generations 6]
  blockopt --rows 1024 --cols 1024 --rate 10 [--n 64] [--threshold 1.1]
  xla      --artifact <stem> (from artifacts/*.hlo.txt)
  export   --model gru --preset timit-mini --rate 10 --out model.grim
  report   [--name fig11|table1|...]  pretty-print bench_out/*.json
  stats    --file out.prom  parse a --stats-out dump and print counters, gauges and histogram quantiles
  profile  model.grimc [--iters 10] [--threads 8] [--json out.json]
           per-layer roofline attribution: the artifact's plan-time cost table (flops, bytes,
           intensity) joined with measured wall/busy time against this machine's ISA peak
  bench-diff old.json new.json [--threshold 5]
           compare two bench reports (bench_kernels, bench_serve, or profile JSON);
           exit 1 when any metric regressed more than the threshold percent"
    );
}

type Flags = HashMap<String, String>;

/// A flag is `--name` or a short `-x` (single dash, non-numeric so a
/// negative number can never be eaten as a flag).
fn is_flag_token(s: &str) -> bool {
    s.strip_prefix("--").map(|k| !k.is_empty()).unwrap_or(false)
        || s.strip_prefix('-')
            .is_some_and(|k| !k.is_empty() && !k.starts_with(|c: char| c.is_ascii_digit()))
}

fn parse_flags(args: &[String]) -> Flags {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if is_flag_token(&args[i]) {
            let key = args[i].trim_start_matches('-').to_string();
            let val = if i + 1 < args.len() && !is_flag_token(&args[i + 1]) {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key, val);
        }
        i += 1;
    }
    out
}

/// Positional (non-flag) arguments, skipping each flag's value token
/// with the same pairing rule as [`parse_flags`].
fn positionals(args: &[String]) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if is_flag_token(&args[i]) {
            if i + 1 < args.len() && !is_flag_token(&args[i + 1]) {
                i += 1; // the flag's value
            }
        } else {
            out.push(args[i].clone());
        }
        i += 1;
    }
    out
}

fn flag<T: std::str::FromStr>(f: &Flags, key: &str, default: T) -> T {
    f.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Parse a `name=value,name2=value2` list (the `--quota` / `--batch-for`
/// flag grammar). Empty input parses to an empty list.
fn parse_kv_list(s: &str) -> anyhow::Result<Vec<(String, usize)>> {
    let mut out = Vec::new();
    for item in s.split(',').map(str::trim).filter(|i| !i.is_empty()) {
        let (name, val) = item
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("expected name=value, got '{item}'"))?;
        let val: usize =
            val.trim().parse().map_err(|_| anyhow::anyhow!("bad value in '{item}'"))?;
        out.push((name.trim().to_string(), val));
    }
    Ok(out)
}

fn model_from_flags(
    f: &Flags,
) -> anyhow::Result<(grim::graph::dsl::Module, grim::compiler::WeightStore)> {
    if let Some(path) = f.get("grim-file") {
        return grim::formats::load_grim(std::path::Path::new(path));
    }
    let kind = ModelKind::parse(&flag(f, "model", "vgg16".to_string()))?;
    let preset = Preset::parse(&flag(f, "preset", "cifar-mini".to_string()))?;
    let opts = InitOptions {
        rate: flag(f, "rate", 8.0),
        block: [flag(f, "block-r", 4usize), flag(f, "block-c", 16usize)],
        seed: flag(f, "seed", 42u64),
    };
    let module = build_model(kind, preset, opts);
    let weights = random_weights(&module, opts);
    Ok((module, weights))
}

fn backend_from_flags(f: &Flags) -> anyhow::Result<Backend> {
    Ok(match flag(f, "backend", "grim".to_string()).as_str() {
        "grim" => Backend::Grim,
        "naive" | "tflite" => Backend::NaiveDense,
        "opt" | "mnn" | "tvm" => Backend::OptDense,
        "csr" => Backend::CsrSparse,
        other => anyhow::bail!("unknown backend '{other}'"),
    })
}

fn input_for(module: &grim::graph::dsl::Module, rng: &mut Rng) -> anyhow::Result<Tensor> {
    let shapes = module.graph.infer_shapes()?;
    let s = &shapes[module.graph.input()?];
    Ok(Tensor::rand_uniform(s.dims(), 1.0, rng))
}

/// AOT compile: run the whole pipeline (encode → fuse → pack → plan)
/// offline and ship the finished plan as a `.grimc` artifact the serving
/// side loads with zero recompilation.
fn cmd_compile(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let backend = backend_from_flags(f)?;
    let mut copts = CompileOptions::for_backend(backend);
    // Artifacts usually cross hosts (compile on a build machine, serve
    // on-device), so `compile` defaults to the generic mobile-core
    // cache model rather than the build host's probed caches;
    // `--cache native` opts into probing for same-host serving. The
    // ISA row of the hardware matrix always comes from the dispatched
    // kernel table (layouts stay valid on any host; the serving side
    // falls back to axpy if its register budget is smaller).
    let cache = match flag(f, "cache", "generic".to_string()).as_str() {
        "generic" => grim::gemm::CacheParams::default(),
        "native" => grim::gemm::CacheParams::detected(),
        other => anyhow::bail!("unknown --cache '{other}' (generic|native)"),
    };
    copts.pack.hw = grim::gemm::HwConfig::for_kernels(grim::gemm::simd::active(), cache);
    copts.dtype = grim::quant::DType::parse(&flag(f, "dtype", "f32".to_string()))?;
    let plan = compile(&module, &weights, copts)?;
    let out = f
        .get("out")
        .or_else(|| f.get("o"))
        .cloned()
        .unwrap_or_else(|| format!("{}.grimc", module.name));
    let path = std::path::Path::new(&out);
    grim::artifact::save_grimc(path, &plan)?;
    let file_bytes = std::fs::metadata(path)?.len() as usize;
    println!("wrote {out}");
    println!("  {}", grim::artifact::describe_stats(&plan, file_bytes));
    Ok(())
}

fn cmd_run(f: &Flags) -> anyhow::Result<()> {
    // .grimc artifacts skip compilation entirely: load and run.
    if let Some(path) = f.get("grimc-file") {
        let plan = grim::artifact::load_grimc(std::path::Path::new(path))?;
        let mut engine = Engine::new(plan, flag(f, "threads", 8usize));
        engine.collect_metrics = true;
        let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
        let mut rng = Rng::new(7);
        let x = Tensor::rand_uniform(&dims, 1.0, &mut rng);
        engine.run(&x)?; // warmup
        let (out, metrics) = engine.run_with_metrics(&x)?;
        println!("model={} (AOT artifact {path})", engine.plan().name);
        println!("output numel={} argmax={}", out.numel(), out.argmax());
        println!("latency: {:.3} ms", metrics.total_ms());
        return Ok(());
    }
    let (module, weights) = model_from_flags(f)?;
    let backend = backend_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::for_backend(backend))?;
    let mut engine = Engine::new(plan, flag(f, "threads", 8usize));
    engine.collect_metrics = true;
    let mut rng = Rng::new(7);
    let x = input_for(&module, &mut rng)?;
    engine.run(&x)?; // warmup
    let (out, metrics) = engine.run_with_metrics(&x)?;
    println!("model={} backend={backend:?}", module.name);
    println!("output numel={} argmax={}", out.numel(), out.argmax());
    println!("latency: {:.3} ms", metrics.total_ms());
    // per-kind time breakdown (profiling view)
    let mut by_kind: std::collections::BTreeMap<&str, f64> = Default::default();
    for l in &metrics.layers {
        *by_kind.entry(l.kind).or_default() += l.micros;
    }
    for (k, us) in by_kind {
        println!("  {k:<8} {:.3} ms", us / 1e3);
    }
    Ok(())
}

fn cmd_inspect(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::default())?;
    println!("model: {}", module.name);
    println!("dense MACs: {}", module.graph.dense_macs()?);
    println!("storage: {} bytes", plan.storage_bytes());
    print!("{}", plan.describe());
    Ok(())
}

/// Turn tracing on when `--trace out.json` was given — BEFORE engines
/// and worker threads are built, so their ring registrations and first
/// spans are captured. Returns the output path.
fn trace_setup(f: &Flags) -> Option<String> {
    let path = f.get("trace").cloned()?;
    grim::obs::trace::enable(flag(f, "trace-sample", 1u64));
    Some(path)
}

/// Export the recorded spans as Chrome trace-event JSON, write them to
/// `path`, and structurally self-validate the document (the CI smoke leg
/// relies on the exit code). `min_models` asserts coverage: a multi-model
/// serve must show spans for at least that many distinct models.
fn write_trace(path: &str, min_models: usize) -> anyhow::Result<()> {
    grim::obs::trace::disable();
    let json = grim::obs::trace::export_chrome();
    std::fs::write(path, &json)?;
    let summary = grim::obs::trace::validate_chrome(&json)?;
    anyhow::ensure!(
        summary.events > 0,
        "trace: no spans recorded (was the server driven with tracing on?)"
    );
    anyhow::ensure!(
        summary.models.len() >= min_models,
        "trace: expected spans from >= {min_models} model(s), saw {:?}",
        summary.models
    );
    println!(
        "trace: {} span(s) across {} model(s) -> {path} (open in ui.perfetto.dev)",
        summary.events,
        summary.models.len()
    );
    Ok(())
}

/// Write the server's Prometheus text dump to `--stats-out` (when given),
/// round-tripping it through the crate's own parser as a self-check.
fn write_stats(f: &Flags, prom: &str) -> anyhow::Result<()> {
    let Some(path) = f.get("stats-out") else { return Ok(()) };
    grim::obs::parse_text(prom)?;
    std::fs::write(path, prom)?;
    println!("stats: wrote {} sample line(s) -> {path}", prom.lines().filter(|l| !l.starts_with('#')).count());
    Ok(())
}

/// `grim profile model.grimc [--iters N] [--threads N] [--json out.json]`:
/// run the artifact N times (after a warmup fifth), join its plan-time
/// cost table with the last run's measured per-step wall/busy time, and
/// print per-layer achieved GFLOP/s, GB/s, and %-of-roofline against
/// this machine's ISA peak. `--json` additionally writes the
/// schema-validated report (the same `grim_bench_schema` shape the bench
/// binaries emit, so `grim bench-diff` works across all of them).
fn cmd_profile(args: &[String], f: &Flags) -> anyhow::Result<()> {
    use grim::obs::prof;
    let path = positionals(args)
        .into_iter()
        .next()
        .or_else(|| f.get("grimc-file").cloned())
        .ok_or_else(|| anyhow::anyhow!("profile needs a .grimc path (grim profile model.grimc)"))?;
    let plan = grim::artifact::load_grimc(std::path::Path::new(&path))?;
    let threads = flag(f, "threads", 8usize);
    let iters = flag(f, "iters", 10usize).max(1);
    let mut engine = Engine::new(plan, threads);
    engine.collect_metrics = true;
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    let mut rng = Rng::new(7);
    let x = Tensor::rand_uniform(&dims, 1.0, &mut rng);
    let machine = prof::MachineModel::detect(threads);

    // Warm/steady split through a HistogramWindow: every run lands in
    // one histogram; the window is read after the warmup fifth, then
    // advanced, so the steady quantiles exclude page-fault and
    // cache-warming noise without a second histogram.
    let hist = std::sync::Arc::new(grim::obs::Histogram::new());
    let mut window = grim::obs::HistogramWindow::new(std::sync::Arc::clone(&hist));
    let warmup = (iters / 5).max(1);
    let mut last = None;
    for _ in 0..warmup {
        let (_, m) = engine.run_with_metrics(&x)?;
        hist.record(m.total_micros().round() as u64);
        last = Some(m);
    }
    let warm_p50 = window.quantile(0.5);
    window.advance();
    for _ in 0..iters {
        let (_, m) = engine.run_with_metrics(&x)?;
        hist.record(m.total_micros().round() as u64);
        last = Some(m);
    }
    let (steady_p50, steady_p99) = (window.quantile(0.5), window.quantile(0.99));
    let metrics = last.expect("iters >= 1");

    let profile = prof::join(&engine.plan().costs, &metrics, &machine)?;
    let model = engine.plan().name.clone();
    let mut report = prof::profile_report(&model, &profile, &machine);
    report
        .meta
        .set("artifact", Json::Str(path.clone()))
        .set("iters", Json::Num(iters as f64))
        .set("warmup_iters", Json::Num(warmup as f64))
        .set("warm_p50_us", Json::Num(warm_p50))
        .set("steady_p50_us", Json::Num(steady_p50))
        .set("steady_p99_us", Json::Num(steady_p99));
    report.print();
    println!(
        "machine: {} x{} @ {:.1} GHz — peak {:.1} GFLOP/s, {:.1} GB/s, ridge {:.2} flop/B",
        machine.isa.name(),
        machine.threads,
        machine.freq_ghz,
        machine.peak_gflops,
        machine.mem_gbps,
        machine.ridge()
    );
    println!(
        "latency: warm p50 {warm_p50:.0} us, steady p50 {steady_p50:.0} us / p99 {steady_p99:.0} us ({iters} iters)"
    );
    if let Some(out) = f.get("json") {
        let obj = report.to_json_with(&machine);
        prof::validate_report(&obj)?;
        std::fs::write(out, obj.to_pretty())?;
        println!("profile: wrote {out}");
    }
    Ok(())
}

/// `grim bench-diff old.json new.json [--threshold pct]`: compare two
/// `grim_bench_schema` reports (any emitter) and exit 1 when a metric
/// moved past the threshold in its worse direction.
fn cmd_bench_diff(args: &[String], f: &Flags) -> anyhow::Result<()> {
    let pos = positionals(args);
    let [old_path, new_path] = &pos[..] else {
        anyhow::bail!("bench-diff needs exactly two report paths (old.json new.json)");
    };
    let threshold = flag(f, "threshold", 5.0f64);
    let old = grim::util::json::parse(&std::fs::read_to_string(old_path)?)?;
    let new = grim::util::json::parse(&std::fs::read_to_string(new_path)?)?;
    let d = grim::obs::prof::diff_reports(&old, &new, threshold)?;
    println!(
        "bench-diff: {} metric cell(s) compared, {} improvement(s), {} regression(s) (threshold {threshold}%)",
        d.compared,
        d.improvements,
        d.regressions.len()
    );
    for r in &d.regressions {
        println!(
            "  REGRESSION {} / {}: {} -> {} ({:+.1}% worse)",
            r.row, r.column, r.old, r.new, r.worse_pct
        );
    }
    if !d.regressions.is_empty() {
        std::process::exit(1);
    }
    Ok(())
}

/// Build a [`ServerConfig`] from the shared serve-flag grammar.
fn server_config_from_flags(f: &Flags) -> anyhow::Result<ServerConfig> {
    let slo_ms: Vec<(String, f64)> =
        parse_kv_list(f.get("slo-ms").map(String::as_str).unwrap_or(""))?
            .into_iter()
            .map(|(m, v)| (m, v as f64))
            .collect();
    let max_inflight = match f.get("max-inflight-batches") {
        Some(v) => Some(
            v.parse()
                .map_err(|_| anyhow::anyhow!("bad --max-inflight-batches '{v}'"))?,
        ),
        None => None,
    };
    Ok(ServerConfig {
        batch: BatchPolicy { max_batch: flag(f, "batch", 8usize), ..BatchPolicy::default() },
        max_inflight,
        slo_ms,
        pending_cap: flag(f, "pending-cap", 256usize),
        ..ServerConfig::default()
    })
}

/// Start the optional `--http` ingress, hold the server open for
/// `--duration` seconds (so external clients can drive it), then stop
/// accepting. No-op without either flag.
fn serve_http_window(f: &Flags, server: &std::sync::Arc<Server>) -> anyhow::Result<()> {
    let http = match f.get("http") {
        Some(addr) => {
            let h = HttpServer::start(std::sync::Arc::clone(server), addr)?;
            println!("http: listening on {}", h.local_addr());
            Some(h)
        }
        None => None,
    };
    let dur = flag(f, "duration", 0.0f64);
    if dur > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(dur));
    }
    if let Some(h) = http {
        println!("http: served {} connection(s)", h.handled());
        h.shutdown();
    }
    Ok(())
}

/// Per-model latency quantiles from a server stats snapshot.
fn print_per_model(stats: &grim::coordinator::ServerStats) {
    for (name, s) in &stats.per_model {
        println!(
            "  {name:<16} n={:<5} p50={:.3}ms p90={:.3}ms p99={:.3}ms",
            s.count, s.p50, s.p90, s.p99
        );
    }
}

/// Multi-model serving: load every `.grimc` in a directory into a
/// registry and drive requests round-robin across the models, asserting
/// every model answers (the CI smoke leg relies on the exit code).
fn cmd_serve_multi(f: &Flags, dir: &str) -> anyhow::Result<()> {
    use grim::exec::Runtime;
    use grim::serving::ModelRegistry;
    use std::sync::Arc;
    let threads = flag(f, "threads", 8usize);
    let budget_mb = flag(f, "budget-mb", 0usize);
    let trace_path = trace_setup(f);
    // One process-wide runtime: every model borrows these workers, so N
    // resident models never exceed `threads` worker threads.
    let runtime = Runtime::new(threads);
    let budget =
        if budget_mb > 0 { budget_mb * 1024 * 1024 } else { usize::MAX };
    let registry = Arc::new(ModelRegistry::with_runtime(Arc::clone(&runtime), budget));
    // Per-model fair-share quotas (`--quota m=2,m2=4`, in worker
    // buckets) — set before loading so engines balance to them at load.
    for (name, q) in parse_kv_list(f.get("quota").map(String::as_str).unwrap_or(""))? {
        let eff = registry.set_quota(&name, q);
        println!("quota: {name} -> {eff} of {threads} worker buckets");
    }
    // Per-model batch-size overrides (`--batch-for m=1`): the batcher
    // consults these instead of the global policy.
    for (name, mb) in parse_kv_list(f.get("batch-for").map(String::as_str).unwrap_or(""))? {
        let policy = grim::coordinator::BatchPolicy {
            max_batch: mb.max(1),
            ..Default::default()
        };
        registry.set_policy(&name, policy);
        println!("batch policy: {name} -> max_batch {}", policy.max_batch);
    }
    let names = registry.load_dir(std::path::Path::new(dir))?;
    anyhow::ensure!(!names.is_empty(), "no .grimc artifacts found in {dir}");
    println!(
        "loaded {} model(s) from {dir} onto one {threads}-thread runtime: {names:?}",
        names.len()
    );
    let config = server_config_from_flags(f)?;
    for (m, t) in &config.slo_ms {
        println!("slo: {m} -> p99 <= {t} ms (dynamic quota governor)");
    }
    let server = Arc::new(Server::start_registry(Arc::clone(&registry), config));
    println!("dispatch: {} concurrent lane(s)", server.dispatch_lanes());

    // Under a tight budget some of the loaded models may already have
    // been LRU-evicted; drive (and assert on) the resident ones.
    let dims: Vec<(String, Vec<usize>)> = names
        .iter()
        .filter_map(|n| {
            let e = registry.get(n)?;
            Some((n.clone(), e.plan().memory.shapes[e.plan().input_id].clone()))
        })
        .collect();
    anyhow::ensure!(!dims.is_empty(), "budget evicted every model");
    for n in &names {
        if !dims.iter().any(|(d, _)| d == n) {
            println!("  (model '{n}' was evicted by the {budget_mb} MiB budget)");
        }
    }
    let n = flag(f, "requests", 64usize);
    let mut rng = Rng::new(11);
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let (name, d) = &dims[i % dims.len()];
        rxs.push((name.clone(), server.submit_to(name, Tensor::rand_uniform(d, 1.0, &mut rng))?));
    }
    let mut per: HashMap<String, u64> = HashMap::new();
    for (name, rx) in rxs {
        let resp = rx.recv()?;
        anyhow::ensure!(resp.error.is_none(), "model '{name}' failed: {:?}", resp.error);
        *per.entry(name).or_default() += 1;
    }
    for (name, _) in &dims {
        anyhow::ensure!(
            per.get(name).copied().unwrap_or(0) > 0,
            "model '{name}' answered no requests"
        );
    }
    serve_http_window(f, &server)?;
    let stats = server.stats();
    println!(
        "completed={} batches={} p50={:.3}ms p99={:.3}ms throughput={:.1} rps",
        stats.completed,
        stats.batches,
        stats.latency_ms.p50,
        stats.latency_ms.p99,
        stats.throughput_rps
    );
    print_per_model(&stats);
    write_stats(f, &server.render_prometheus())?;
    if let Some(path) = &trace_path {
        write_trace(path, dims.len().min(2))?;
    }
    for ms in registry.stats() {
        println!(
            "  {:<16} {:>8} KiB resident, {} requests over {} arena(s) of {} KiB{}{}",
            ms.name,
            ms.resident_bytes / 1024,
            ms.pool.checkouts,
            ms.pool.arenas_created,
            ms.pool.arena_bytes / 1024,
            match ms.quota {
                Some(q) => format!(", quota {q}"),
                None => String::new(),
            },
            if ms.not_resident > 0 {
                format!(", {} not-resident misses", ms.not_resident)
            } else {
                String::new()
            }
        );
    }
    if let Some(b) = registry.budget_bytes() {
        println!(
            "budget: {} / {} KiB resident, {} eviction(s)",
            registry.resident_bytes() / 1024,
            b / 1024,
            registry.evictions()
        );
    }
    Ok(())
}

fn cmd_serve(f: &Flags) -> anyhow::Result<()> {
    if let Some(dir) = f.get("models") {
        return cmd_serve_multi(f, dir);
    }
    let trace_path = trace_setup(f);
    let (module, weights) = model_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::default())?;
    let engine = Engine::new(plan, flag(f, "threads", 8usize));
    let config = server_config_from_flags(f)?;
    let server = std::sync::Arc::new(Server::start(engine, config));
    let n = flag(f, "requests", 64usize);
    let mut rng = Rng::new(11);
    println!("serving {n} requests on {} ({} dispatch lane(s)) ...", module.name, server.dispatch_lanes());
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit(input_for(&module, &mut rng)?)?);
    }
    for rx in rxs {
        rx.recv()?;
    }
    serve_http_window(f, &server)?;
    write_stats(f, &server.render_prometheus())?;
    let stats = server.stats();
    println!(
        "completed={} batches={} p50={:.3}ms p90={:.3}ms p99={:.3}ms throughput={:.1} rps",
        stats.completed,
        stats.batches,
        stats.latency_ms.p50,
        stats.latency_ms.p90,
        stats.latency_ms.p99,
        stats.throughput_rps
    );
    print_per_model(&stats);
    println!(
        "arena: {} KiB x{} ({} checkouts, zero per-request allocation)",
        stats.arena.arena_bytes / 1024,
        stats.arena.arenas_created,
        stats.arena.checkouts
    );
    if let Some(path) = &trace_path {
        write_trace(path, 1)?;
    }
    Ok(())
}

fn cmd_tune(f: &Flags) -> anyhow::Result<()> {
    use grim::gemm::pack::pack_bcrc;
    use grim::tuner::{tune_layer, GaConfig, SearchSpace};
    use std::sync::Arc;
    let (module, weights) = model_from_flags(f)?;
    let ga = GaConfig {
        generations: flag(f, "generations", 4usize),
        population: flag(f, "population", 8usize),
        ..Default::default()
    };
    // Scalar-vs-SIMD backend gene *and* the packed-layout cache-block
    // genes: fitness runs the exact kc×mc packed layout those genes
    // would ship, so (unroll, n_tile, pack_kc, pack_mc) are tuned
    // against the layout the compiled plan executes — not the
    // encode-order fallback.
    let space = SearchSpace { simds: vec![true, false], ..SearchSpace::with_pack_axis() };
    println!("tuning {} (pop={} gen={})", module.name, ga.population, ga.generations);
    const TUNE_N: usize = 32;
    for node in module.graph.weighted_layers() {
        let Some(lw) = weights.get(&node.name) else { continue };
        let Some(mask) = &lw.mask else { continue };
        let enc = grim::sparse::Bcrc::from_masked(&lw.w, mask);
        let (rows, cols) = lw.w.shape().as_matrix();
        let mut rng = Rng::new(5);
        let x = Tensor::rand_uniform(&[cols, TUNE_N], 1.0, &mut rng);
        // Packing is a one-time compile cost, so it must not pollute the
        // latency measurement: memoize one packed layout per distinct
        // layout-relevant gene tuple, built on the candidate's first
        // (warmup) invocation and reused by every timed iteration.
        #[allow(clippy::type_complexity)]
        let mut packs: HashMap<
            (usize, usize, bool, usize, usize, usize),
            Arc<grim::sparse::PackedBcrc>,
        > = HashMap::new();
        let res = tune_layer(&space, ga, |cfg| {
            let key = (cfg.unroll, cfg.n_tile, cfg.lre, cfg.pack_kc, cfg.pack_mc, cfg.pack_mr);
            let packed = Arc::clone(packs.entry(key).or_insert_with(|| {
                // Same hardware matrix the compile path defaults to
                // (PackOptions::default), so 'auto' genes are measured
                // on the exact layout the shipped plan will use.
                Arc::new(pack_bcrc(
                    &enc,
                    cfg.gemm_params(),
                    TUNE_N,
                    grim::gemm::HwConfig::detected(),
                    cfg.pack_overrides(),
                ))
            }));
            let g = grim::gemm::BcrcGemm::new(enc.clone(), cfg.gemm_params()).with_packed(packed);
            std::hint::black_box(g.execute(&x));
        });
        let pack_gene = |v: usize| if v == 0 { "auto".to_string() } else { v.to_string() };
        println!(
            "  {:<16} [{rows}x{cols}] -> unroll={} tile={} pack_kc={} pack_mc={} pack_mr={} backend={} ({:.4} ms, {} evals)",
            node.name,
            res.best.unroll,
            res.best.n_tile,
            pack_gene(res.best.pack_kc),
            pack_gene(res.best.pack_mc),
            pack_gene(res.best.pack_mr),
            if res.best.simd { grim::gemm::simd::active().name } else { "scalar" },
            res.best_ms,
            res.evals
        );
    }
    Ok(())
}

fn cmd_blockopt(f: &Flags) -> anyhow::Result<()> {
    use grim::blockopt::{default_candidates, find_opt_block};
    use grim::util::ThreadPool;
    let rows = flag(f, "rows", 1024usize);
    let cols = flag(f, "cols", 1024usize);
    let rate = flag(f, "rate", 10.0f64);
    let n = flag(f, "n", 64usize);
    let threshold = flag(f, "threshold", 1.1f64);
    let pool = ThreadPool::new(flag(f, "threads", 8usize));
    let cands = default_candidates(rows, cols);
    let res = find_opt_block(rows, cols, rate, &cands, n, threshold, &pool, 17);
    println!("block-size search for [{rows}x{cols}] @ {rate}x, N={n}:");
    for (b, ms) in &res.tried {
        println!("  block {:>4}x{:<3} -> {:.4} ms", b[0], b[1], ms);
    }
    println!("optimal block: {}x{} ({:.4} ms)", res.opt_block[0], res.opt_block[1], res.opt_ms);
    Ok(())
}

fn cmd_xla(f: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::default_dir();
    let stems = store.list();
    anyhow::ensure!(!stems.is_empty(), "no artifacts found — run `make artifacts`");
    let stem = flag(f, "artifact", stems[0].clone());
    println!("available artifacts: {stems:?}");
    let model = store.load(&stem)?;
    println!("loaded + compiled '{}'", model.name());
    Ok(())
}

fn cmd_report(f: &Flags) -> anyhow::Result<()> {
    use grim::util::json;
    let dir = std::path::Path::new("bench_out");
    anyhow::ensure!(dir.exists(), "bench_out/ not found — run `cargo bench` or `make tableN` first");
    let filter = f.get("name").cloned();
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .flatten()
        .filter(|e| e.path().extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let stem = e.path().file_stem().unwrap().to_string_lossy().to_string();
        if let Some(fname) = &filter {
            if &stem != fname {
                continue;
            }
        }
        let text = std::fs::read_to_string(e.path())?;
        let v = json::parse(&text)?;
        if let (Some(title), Some(cols), Some(rows)) = (
            v.get("title").and_then(|t| t.as_str()),
            v.get("columns").and_then(|c| c.as_arr()),
            v.get("rows").and_then(|r| r.as_arr()),
        ) {
            // bench Report format
            println!("\n=== {title} ===");
            let header: Vec<&str> = cols.iter().filter_map(|c| c.as_str()).collect();
            println!("{}", header.join("  "));
            for r in rows {
                if let Some(cells) = r.as_arr() {
                    let line: Vec<&str> = cells.iter().filter_map(|c| c.as_str()).collect();
                    println!("{}", line.join("  "));
                }
            }
        } else {
            // python experiment format (tables 1-3)
            println!("\n=== {stem} ===");
            if let Some(rows) = v.get("rows").and_then(|r| r.as_arr()) {
                for r in rows {
                    let scheme = r.get("scheme").and_then(|x| x.as_str()).unwrap_or("?");
                    let rate = r.get("rate").and_then(|x| x.as_f64()).unwrap_or(0.0);
                    let acc = r
                        .get("sparse")
                        .or_else(|| r.get("sparse_per"))
                        .and_then(|x| x.as_f64());
                    match acc {
                        Some(a) => println!("  {scheme:>10} @ {rate:>6.1}x -> {a:.3}"),
                        None => println!("  {scheme:>10} @ {rate:>6.1}x -> (failed)"),
                    }
                }
            }
        }
    }
    Ok(())
}

/// Parse a `--stats-out` Prometheus dump and pretty-print it: plain
/// counters/gauges first, then one quantile row per histogram series
/// (reconstructed from its cumulative `_bucket` lines). Exits non-zero
/// on any parse failure, which the CI smoke leg relies on.
fn cmd_stats(f: &Flags) -> anyhow::Result<()> {
    let path = f
        .get("file")
        .ok_or_else(|| anyhow::anyhow!("stats: --file <out.prom> is required"))?;
    let text = std::fs::read_to_string(path)?;
    let samples = grim::obs::parse_text(&text)?;
    let hists = grim::obs::fold_histograms(&samples);
    // Scalar series = everything that is not part of a histogram family.
    let hist_prefixes: Vec<String> = hists.iter().map(|h| h.name.clone()).collect();
    let is_hist_part = |n: &str| {
        hist_prefixes.iter().any(|p| {
            n == format!("{p}_bucket") || n == format!("{p}_sum") || n == format!("{p}_count")
        })
    };
    println!("== scalars ==");
    for s in samples.iter().filter(|s| !is_hist_part(&s.name)) {
        let labels = if s.labels.is_empty() {
            String::new()
        } else {
            format!(
                "{{{}}}",
                s.labels.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(",")
            )
        };
        println!("  {}{labels} = {}", s.name, s.value);
    }
    println!("== histograms ==");
    for h in &hists {
        let labels = h
            .labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",");
        println!(
            "  {}{{{labels}}} n={} mean={:.1} p50={:.1} p90={:.1} p99={:.1}",
            h.name,
            h.count,
            if h.count > 0.0 { h.sum / h.count } else { 0.0 },
            h.quantile(0.50),
            h.quantile(0.90),
            h.quantile(0.99)
        );
    }
    Ok(())
}

fn cmd_export(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let out = flag(f, "out", "model.grim".to_string());
    grim::formats::save_grim(std::path::Path::new(&out), &module, &weights)?;
    println!("wrote {out} ({} layers)", weights.len());
    Ok(())
}
