//! `grim` — the CLI leader binary.
//!
//! Subcommands:
//!   serve     start the inference server on a model and drive a workload
//!   run       single inference on a model (random or .grim weights)
//!   inspect   compile a model and print its execution plan
//!   tune      auto-tune a model's layers (GA), print chosen configs
//!   blockopt  run the Listing-1 block-size optimizer for a layer shape
//!   xla       load + execute an AOT HLO artifact (jax bridge smoke test)
//!   export    build a model with random BCR weights and save a .grim
//!
//! No clap in the vendored dep set — a hand-rolled flag parser keeps the
//! surface small.

use grim::compiler::passes::{compile, Backend, CompileOptions};
use grim::coordinator::{Server, ServerConfig};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::runtime::ArtifactStore;
use grim::tensor::Tensor;
use grim::util::Rng;
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let flags = parse_flags(&args[1..]);
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&flags),
        "run" => cmd_run(&flags),
        "inspect" => cmd_inspect(&flags),
        "tune" => cmd_tune(&flags),
        "blockopt" => cmd_blockopt(&flags),
        "xla" => cmd_xla(&flags),
        "export" => cmd_export(&flags),
        "report" => cmd_report(&flags),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "grim — BCR-sparse real-time DNN inference (paper reproduction)

USAGE: grim <command> [--flag value ...]

COMMANDS:
  serve    --model vgg16 --preset cifar-mini --rate 8 --threads 8 --requests 64 --batch 8
  run      --model resnet18 --preset cifar-mini --rate 8 [--grim-file m.grim] [--backend grim|naive|opt|csr]
  inspect  --model vgg16 --preset cifar-mini --rate 8
  tune     --model vgg16 --preset cifar-mini --rate 8 [--generations 6]
  blockopt --rows 1024 --cols 1024 --rate 10 [--n 64] [--threshold 1.1]
  xla      --artifact <stem> (from artifacts/*.hlo.txt)
  export   --model gru --preset timit-mini --rate 10 --out model.grim
  report   [--name fig11|table1|...]  pretty-print bench_out/*.json"
    );
}

type Flags = HashMap<String, String>;

fn parse_flags(args: &[String]) -> Flags {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn flag<T: std::str::FromStr>(f: &Flags, key: &str, default: T) -> T {
    f.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn model_from_flags(
    f: &Flags,
) -> anyhow::Result<(grim::graph::dsl::Module, grim::compiler::WeightStore)> {
    if let Some(path) = f.get("grim-file") {
        return grim::formats::load_grim(std::path::Path::new(path));
    }
    let kind = ModelKind::parse(&flag(f, "model", "vgg16".to_string()))?;
    let preset = Preset::parse(&flag(f, "preset", "cifar-mini".to_string()))?;
    let opts = InitOptions {
        rate: flag(f, "rate", 8.0),
        block: [flag(f, "block-r", 4usize), flag(f, "block-c", 16usize)],
        seed: flag(f, "seed", 42u64),
    };
    let module = build_model(kind, preset, opts);
    let weights = random_weights(&module, opts);
    Ok((module, weights))
}

fn backend_from_flags(f: &Flags) -> anyhow::Result<Backend> {
    Ok(match flag(f, "backend", "grim".to_string()).as_str() {
        "grim" => Backend::Grim,
        "naive" | "tflite" => Backend::NaiveDense,
        "opt" | "mnn" | "tvm" => Backend::OptDense,
        "csr" => Backend::CsrSparse,
        other => anyhow::bail!("unknown backend '{other}'"),
    })
}

fn input_for(module: &grim::graph::dsl::Module, rng: &mut Rng) -> anyhow::Result<Tensor> {
    let shapes = module.graph.infer_shapes()?;
    let s = &shapes[module.graph.input()?];
    Ok(Tensor::rand_uniform(s.dims(), 1.0, rng))
}

fn cmd_run(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let backend = backend_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::for_backend(backend))?;
    let mut engine = Engine::new(plan, flag(f, "threads", 8usize));
    engine.collect_metrics = true;
    let mut rng = Rng::new(7);
    let x = input_for(&module, &mut rng)?;
    engine.run(&x)?; // warmup
    let (out, metrics) = engine.run_with_metrics(&x)?;
    println!("model={} backend={backend:?}", module.name);
    println!("output numel={} argmax={}", out.numel(), out.argmax());
    println!("latency: {:.3} ms", metrics.total_ms());
    // per-kind time breakdown (profiling view)
    let mut by_kind: std::collections::BTreeMap<&str, f64> = Default::default();
    for l in &metrics.layers {
        *by_kind.entry(l.kind).or_default() += l.micros;
    }
    for (k, us) in by_kind {
        println!("  {k:<8} {:.3} ms", us / 1e3);
    }
    Ok(())
}

fn cmd_inspect(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::default())?;
    println!("model: {}", module.name);
    println!("dense MACs: {}", module.graph.dense_macs()?);
    println!("storage: {} bytes", plan.storage_bytes());
    print!("{}", plan.describe());
    Ok(())
}

fn cmd_serve(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let plan = compile(&module, &weights, CompileOptions::default())?;
    let engine = Engine::new(plan, flag(f, "threads", 8usize));
    let mut config = ServerConfig::default();
    config.batch.max_batch = flag(f, "batch", 8usize);
    let server = Server::start(engine, config);
    let n = flag(f, "requests", 64usize);
    let mut rng = Rng::new(11);
    println!("serving {n} requests on {} ...", module.name);
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit(input_for(&module, &mut rng)?)?);
    }
    for rx in rxs {
        rx.recv()?;
    }
    let stats = server.shutdown();
    println!(
        "completed={} batches={} p50={:.3}ms p90={:.3}ms p99={:.3}ms throughput={:.1} rps",
        stats.completed,
        stats.batches,
        stats.latency_ms.p50,
        stats.latency_ms.p90,
        stats.latency_ms.p99,
        stats.throughput_rps
    );
    println!(
        "arena: {} KiB x{} ({} checkouts, zero per-request allocation)",
        stats.arena.arena_bytes / 1024,
        stats.arena.arenas_created,
        stats.arena.checkouts
    );
    Ok(())
}

fn cmd_tune(f: &Flags) -> anyhow::Result<()> {
    use grim::tuner::{tune_layer, GaConfig, SearchSpace};
    let (module, weights) = model_from_flags(f)?;
    let ga = GaConfig {
        generations: flag(f, "generations", 4usize),
        population: flag(f, "population", 8usize),
        ..Default::default()
    };
    // Include the scalar-vs-SIMD backend gene: (unroll, n_tile) are
    // measured against the dispatched kernels, and a layer may still pick
    // scalar when vectorization loses on it.
    let space = SearchSpace::with_simd_axis();
    println!("tuning {} (pop={} gen={})", module.name, ga.population, ga.generations);
    for node in module.graph.weighted_layers() {
        let Some(lw) = weights.get(&node.name) else { continue };
        let Some(mask) = &lw.mask else { continue };
        let enc = grim::sparse::Bcrc::from_masked(&lw.w, mask);
        let (rows, cols) = lw.w.shape().as_matrix();
        let mut rng = Rng::new(5);
        let x = Tensor::rand_uniform(&[cols, 32], 1.0, &mut rng);
        let res = tune_layer(&space, ga, |cfg| {
            let g = grim::gemm::BcrcGemm::new(enc.clone(), cfg.gemm_params());
            std::hint::black_box(g.execute(&x));
        });
        println!(
            "  {:<16} [{rows}x{cols}] -> unroll={} tile={} backend={} ({:.4} ms, {} evals)",
            node.name,
            res.best.unroll,
            res.best.n_tile,
            if res.best.simd { grim::gemm::simd::active().name } else { "scalar" },
            res.best_ms,
            res.evals
        );
    }
    Ok(())
}

fn cmd_blockopt(f: &Flags) -> anyhow::Result<()> {
    use grim::blockopt::{default_candidates, find_opt_block};
    use grim::util::ThreadPool;
    let rows = flag(f, "rows", 1024usize);
    let cols = flag(f, "cols", 1024usize);
    let rate = flag(f, "rate", 10.0f64);
    let n = flag(f, "n", 64usize);
    let threshold = flag(f, "threshold", 1.1f64);
    let pool = ThreadPool::new(flag(f, "threads", 8usize));
    let cands = default_candidates(rows, cols);
    let res = find_opt_block(rows, cols, rate, &cands, n, threshold, &pool, 17);
    println!("block-size search for [{rows}x{cols}] @ {rate}x, N={n}:");
    for (b, ms) in &res.tried {
        println!("  block {:>4}x{:<3} -> {:.4} ms", b[0], b[1], ms);
    }
    println!("optimal block: {}x{} ({:.4} ms)", res.opt_block[0], res.opt_block[1], res.opt_ms);
    Ok(())
}

fn cmd_xla(f: &Flags) -> anyhow::Result<()> {
    let store = ArtifactStore::default_dir();
    let stems = store.list();
    anyhow::ensure!(!stems.is_empty(), "no artifacts found — run `make artifacts`");
    let stem = flag(f, "artifact", stems[0].clone());
    println!("available artifacts: {stems:?}");
    let model = store.load(&stem)?;
    println!("loaded + compiled '{}'", model.name());
    Ok(())
}

fn cmd_report(f: &Flags) -> anyhow::Result<()> {
    use grim::util::json;
    let dir = std::path::Path::new("bench_out");
    anyhow::ensure!(dir.exists(), "bench_out/ not found — run `cargo bench` or `make tableN` first");
    let filter = f.get("name").cloned();
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .flatten()
        .filter(|e| e.path().extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let stem = e.path().file_stem().unwrap().to_string_lossy().to_string();
        if let Some(fname) = &filter {
            if &stem != fname {
                continue;
            }
        }
        let text = std::fs::read_to_string(e.path())?;
        let v = json::parse(&text)?;
        if let (Some(title), Some(cols), Some(rows)) = (
            v.get("title").and_then(|t| t.as_str()),
            v.get("columns").and_then(|c| c.as_arr()),
            v.get("rows").and_then(|r| r.as_arr()),
        ) {
            // bench Report format
            println!("\n=== {title} ===");
            let header: Vec<&str> = cols.iter().filter_map(|c| c.as_str()).collect();
            println!("{}", header.join("  "));
            for r in rows {
                if let Some(cells) = r.as_arr() {
                    let line: Vec<&str> = cells.iter().filter_map(|c| c.as_str()).collect();
                    println!("{}", line.join("  "));
                }
            }
        } else {
            // python experiment format (tables 1-3)
            println!("\n=== {stem} ===");
            if let Some(rows) = v.get("rows").and_then(|r| r.as_arr()) {
                for r in rows {
                    let scheme = r.get("scheme").and_then(|x| x.as_str()).unwrap_or("?");
                    let rate = r.get("rate").and_then(|x| x.as_f64()).unwrap_or(0.0);
                    let acc = r
                        .get("sparse")
                        .or_else(|| r.get("sparse_per"))
                        .and_then(|x| x.as_f64());
                    match acc {
                        Some(a) => println!("  {scheme:>10} @ {rate:>6.1}x -> {a:.3}"),
                        None => println!("  {scheme:>10} @ {rate:>6.1}x -> (failed)"),
                    }
                }
            }
        }
    }
    Ok(())
}

fn cmd_export(f: &Flags) -> anyhow::Result<()> {
    let (module, weights) = model_from_flags(f)?;
    let out = flag(f, "out", "model.grim".to_string());
    grim::formats::save_grim(std::path::Path::new(&out), &module, &weights)?;
    println!("wrote {out} ({} layers)", weights.len());
    Ok(())
}
