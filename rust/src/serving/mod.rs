//! Multi-model serving: the [`registry::ModelRegistry`] of named,
//! hot-loadable engines.
//!
//! The coordinator ([`crate::coordinator`]) owns the request path (queue
//! → batcher → scheduler); this module owns *which models exist*: each
//! named model is an [`crate::engine::Engine`] — typically reconstructed
//! from a `.grimc` artifact ([`crate::artifact`]) with zero re-compilation
//! — holding its own isolated [`crate::memory::WorkspacePool`] and worker
//! pool. The registry tracks per-model resident bytes (weights + packed
//! buffers + arena) against an optional budget and evicts
//! least-recently-used models when loading a new one would exceed it —
//! the many-model serving tier the ROADMAP's production north star asks
//! for.

pub mod registry;

pub use registry::{plan_resident_bytes, ModelRegistry, ModelStats};
