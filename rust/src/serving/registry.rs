//! The model registry: named engines, hot-loaded from `.grimc`
//! artifacts, sharing **one** process-wide execution runtime, with
//! per-model workspace pools, fair-share quotas, batch-policy
//! overrides, and a resident-bytes LRU eviction budget.
//!
//! Design notes:
//!
//! * **One scheduler** — the registry owns a single
//!   [`crate::exec::Runtime`]; every engine it builds *borrows* that
//!   runtime instead of spawning a private pool, so N resident models
//!   keep the process at exactly the runtime's worker count (the old
//!   N×T thread explosion is structurally impossible). Per-model
//!   quotas ([`ModelRegistry::set_quota`]) bound how many worker
//!   buckets a model's static schedules use — applied as a
//!   pure-metadata rebalance, never a packed-buffer copy.
//! * **Memory isolation** — every model still gets its own [`Engine`]
//!   with its own [`crate::memory::WorkspacePool`] (arenas sized to
//!   *that* plan). One model's traffic can never corrupt or observe
//!   another's arenas; per-model stats come straight from the pool.
//! * **Hot loading** — the registry is shared behind an `Arc`; models can
//!   be inserted or evicted while a
//!   [`crate::coordinator::Server`] is routing requests over it. The
//!   scheduler resolves names at execution time, so a request for an
//!   evicted model fails with a clear error instead of silently pinning
//!   the engine's memory.
//! * **Budget** — `resident bytes` per model = weight storage + packed
//!   buffers + one workspace arena ([`plan_resident_bytes`]). When an
//!   insert pushes the total over the budget, least-recently-*used*
//!   models (both `get` and insert bump recency) are evicted until it
//!   fits; the incoming model itself is never evicted, so a single
//!   over-budget model still serves (better than serving nothing).
//!   In-flight requests holding the evicted `Arc<Engine>` finish
//!   normally; the memory is freed when the last handle drops.

use crate::compiler::plan::ExecutionPlan;
use crate::coordinator::BatchPolicy;
use crate::engine::Engine;
use crate::exec::Runtime;
use crate::memory::PoolStats;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Bytes a loaded model keeps resident: weight storage (dense tensors or
/// sparse encodings), the packed weight buffers the packing pass added,
/// and one workspace arena (steady-state single-stream serving; each
/// additional concurrent request adds one arena).
pub fn plan_resident_bytes(plan: &ExecutionPlan) -> usize {
    plan.storage_bytes() + plan.packing.packed_bytes + plan.memory.arena_bytes()
}

struct Entry {
    engine: Arc<Engine>,
    resident: usize,
    last_used: u64,
}

/// Per-model stats snapshot (see [`ModelRegistry::stats`]).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    /// Weights + packed buffers + one arena, in bytes.
    pub resident_bytes: usize,
    /// This model's isolated workspace-pool telemetry; `checkouts` is the
    /// number of inferences the model has served.
    pub pool: PoolStats,
    /// Weight bytes resident per value type (`f32` storage vs packed
    /// `i8` codes + their row sums) — the quantization win, per model.
    pub weight_bytes: [(crate::quant::DType, usize); 2],
    /// Fair-share quota in shared-runtime worker buckets, when set.
    pub quota: Option<usize>,
    /// Requests that targeted this model while it was not resident
    /// (admission control hooks on this).
    pub not_resident: u64,
}

/// Named-model registry with a shared execution runtime and LRU
/// eviction under a resident-bytes budget.
pub struct ModelRegistry {
    /// The one process-wide scheduler every engine borrows.
    runtime: Arc<Runtime>,
    /// Resident-bytes ceiling (`usize::MAX` = unlimited).
    budget: usize,
    inner: Mutex<HashMap<String, Entry>>,
    /// Logical LRU clock (bumped on every insert and `get`).
    clock: AtomicU64,
    evictions: AtomicU64,
    /// Per-model batching-policy overrides (survive eviction, so a
    /// reloaded model keeps its knobs).
    policies: Mutex<HashMap<String, BatchPolicy>>,
    /// Per-model count of requests that missed (model not resident).
    misses: Mutex<HashMap<String, u64>>,
    /// Where `.grimc` artifacts live for **background loads**: a request
    /// for a non-resident model whose artifact exists here is parked and
    /// the model loaded off the request path instead of erroring.
    /// Set explicitly or implicitly by [`Self::load_dir`].
    artifact_dir: Mutex<Option<std::path::PathBuf>>,
    /// Serializes quota store + engine rebalance so concurrent
    /// `set_quota`/`insert_engine` calls cannot interleave into a
    /// stored-quota/active-schedule mismatch.
    quota_apply: Mutex<()>,
}

impl ModelRegistry {
    /// Registry without a resident-bytes budget, over a fresh
    /// `threads`-worker runtime.
    pub fn new(threads: usize) -> Self {
        Self::with_budget(threads, usize::MAX)
    }

    /// Registry enforcing `budget_bytes` of total model residency, over
    /// a fresh `threads`-worker runtime.
    pub fn with_budget(threads: usize, budget_bytes: usize) -> Self {
        Self::with_runtime(Runtime::new(threads), budget_bytes)
    }

    /// Registry over an **existing** shared runtime — several registries
    /// (or a registry plus standalone engines) can borrow one scheduler.
    pub fn with_runtime(runtime: Arc<Runtime>, budget_bytes: usize) -> Self {
        ModelRegistry {
            runtime,
            budget: budget_bytes.max(1),
            inner: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            policies: Mutex::new(HashMap::new()),
            misses: Mutex::new(HashMap::new()),
            artifact_dir: Mutex::new(None),
            quota_apply: Mutex::new(()),
        }
    }

    /// Declare where `.grimc` artifacts for this registry live, enabling
    /// background loads of cold models ([`Self::artifact_path_for`]).
    /// [`Self::load_dir`] sets this automatically.
    pub fn set_artifact_dir(&self, dir: impl Into<std::path::PathBuf>) {
        *self.artifact_dir.lock().unwrap() = Some(dir.into());
    }

    /// The configured artifact directory, if any.
    pub fn artifact_dir(&self) -> Option<std::path::PathBuf> {
        self.artifact_dir.lock().unwrap().clone()
    }

    /// Path of the on-disk artifact that could back model `name`
    /// (`<artifact_dir>/<name>.grimc`), if the directory is configured
    /// and the file exists. Names with path separators are rejected —
    /// the model namespace must not become a filesystem traversal.
    pub fn artifact_path_for(&self, name: &str) -> Option<std::path::PathBuf> {
        if name.is_empty() || name.contains('/') || name.contains('\\') || name.contains("..") {
            return None;
        }
        let dir = self.artifact_dir.lock().unwrap().clone()?;
        let path = dir.join(format!("{name}.grimc"));
        path.is_file().then_some(path)
    }

    /// The shared runtime all registry engines dispatch on.
    pub fn runtime(&self) -> Arc<Runtime> {
        Arc::clone(&self.runtime)
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register an already-built engine under `name` (replacing any
    /// previous model of that name), then evict LRU models while over
    /// budget. Returns the shared engine handle.
    pub fn insert_engine(&self, name: impl Into<String>, engine: Engine) -> Arc<Engine> {
        let name = name.into();
        // Served engines always collect per-layer metrics: the server's
        // per-kernel-kind step histograms feed from `run_with_metrics`,
        // and the per-step overhead (one Instant + two atomic reads per
        // layer) is noise next to the kernels themselves.
        let mut engine = engine;
        engine.collect_metrics = true;
        // The one-pool invariant is structural: a registry engine MUST
        // dispatch on the registry's runtime, or the process grows extra
        // worker pools and quota rebalances would steer a pool the
        // registry does not own. Build engines with `insert_plan` or
        // `Engine::with_runtime(plan, registry.runtime())`.
        assert!(
            Arc::ptr_eq(&engine.runtime(), &self.runtime),
            "registry engines must borrow the registry's shared runtime"
        );
        let resident = plan_resident_bytes(engine.plan());
        let engine = Arc::new(engine);
        // Entries removed under the lock are torn down *after* it is
        // released: dropping an Engine releases its buffers (and, for a
        // private-runtime engine, joins its pool), which must not stall
        // concurrent request routing.
        let mut dropped: Vec<Entry> = Vec::new();
        {
            let mut g = self.inner.lock().unwrap();
            let last_used = self.tick();
            if let Some(old) =
                g.insert(name.clone(), Entry { engine: Arc::clone(&engine), resident, last_used })
            {
                dropped.push(old);
            }
            self.evict_over_budget(&mut g, &name, &mut dropped);
        }
        drop(dropped);
        // Reconcile the engine's schedule width with the quota AFTER the
        // entry is resident: quotas are keyed by the registry name (not
        // the plan's internal name), and a `set_quota`/`clear_quota`
        // racing the insert either already updated the store (read here,
        // under the apply lock) or will find the engine via `peek` — in
        // every interleaving the engine converges to the stored state.
        // Unconditional reconcile, so a quota *cleared* mid-insert also
        // snaps back to the full pool width; the fast path (engine
        // already at the target — `insert_plan` pre-read it) rebuilds
        // nothing.
        {
            let _apply = self.quota_apply.lock().unwrap();
            let want = self.runtime.effective_threads(&name);
            if engine.schedules().threads != want {
                engine.rebalance(want);
            }
        }
        engine
    }

    /// Set `model`'s fair-share quota (worker buckets on the shared
    /// runtime; clamped to `1..=threads`) and rebalance the resident
    /// engine's schedules to it — pure metadata, no packed-buffer
    /// copies, applied atomically between inferences. Returns the
    /// effective quota.
    pub fn set_quota(&self, model: &str, buckets: usize) -> usize {
        // Store + rebalance under the apply lock: two racing set_quota
        // calls (or a set_quota racing an insert) serialize, so the
        // stored quota and the engine's active schedule width cannot
        // end up permanently out of sync.
        let _apply = self.quota_apply.lock().unwrap();
        let eff = self.runtime.set_quota(model, buckets);
        if let Some(engine) = self.peek(model) {
            engine.rebalance(eff);
        }
        eff
    }

    /// Remove `model`'s quota, rebalancing back to the full pool width.
    pub fn clear_quota(&self, model: &str) {
        let _apply = self.quota_apply.lock().unwrap();
        self.runtime.clear_quota(model);
        if let Some(engine) = self.peek(model) {
            engine.rebalance(self.runtime.threads());
        }
    }

    /// Override `model`'s batching policy (consumed by the server's
    /// batcher instead of the global default; survives eviction).
    pub fn set_policy(&self, model: &str, policy: BatchPolicy) {
        self.policies.lock().unwrap().insert(model.to_string(), policy);
    }

    /// The batching-policy override for `model`, if any.
    pub fn policy_for(&self, model: &str) -> Option<BatchPolicy> {
        self.policies.lock().unwrap().get(model).copied()
    }

    /// Record a request that targeted `model` while it was not resident.
    /// The map is keyed by client-supplied names, so it is capped: once
    /// [`Self::MISS_NAME_CAP`] distinct names are tracked, misses for
    /// *new* names fold into the `"*"` overflow bucket instead of
    /// growing the map (a fuzzer rotating model names cannot leak
    /// memory in a long-running server).
    pub fn note_miss(&self, model: &str) {
        self.note_misses(model, 1);
    }

    /// [`Self::note_miss`] for a whole batch: one lock, one entry.
    pub fn note_misses(&self, model: &str, count: u64) {
        if count == 0 {
            return;
        }
        let mut g = self.misses.lock().unwrap();
        if g.contains_key(model) || g.len() < Self::MISS_NAME_CAP {
            *g.entry(model.to_string()).or_default() += count;
        } else {
            *g.entry("*".to_string()).or_default() += count;
        }
    }

    /// Distinct non-resident model names tracked before misses fold
    /// into the `"*"` overflow bucket.
    pub const MISS_NAME_CAP: usize = 1024;

    /// Requests that targeted `model` while it was not resident (`"*"`
    /// reads the overflow bucket).
    pub fn not_resident(&self, model: &str) -> u64 {
        self.misses.lock().unwrap().get(model).copied().unwrap_or(0)
    }

    /// Look a model up *without* bumping its LRU recency (internal
    /// bookkeeping must not distort eviction order).
    fn peek(&self, name: &str) -> Option<Arc<Engine>> {
        self.inner.lock().unwrap().get(name).map(|e| Arc::clone(&e.engine))
    }

    /// Build an engine for `plan` **on the shared runtime** (no new
    /// threads) and register it; the engine's schedules are balanced to
    /// the model's quota (read up front so a quota'd load builds its
    /// schedules exactly once — the post-insert application in
    /// `insert_engine` then degenerates to a no-op check).
    pub fn insert_plan(&self, name: impl Into<String>, plan: ExecutionPlan) -> Arc<Engine> {
        let name = name.into();
        let buckets = self.runtime.effective_threads(&name);
        let engine = Engine::with_runtime_buckets(plan, Arc::clone(&self.runtime), buckets);
        self.insert_engine(name, engine)
    }

    /// Hot-load a `.grimc` artifact as model `name` — the full AOT path:
    /// no graph compilation, no BCR re-encoding, no re-packing.
    pub fn load_file(&self, name: impl Into<String>, path: &Path) -> anyhow::Result<Arc<Engine>> {
        Ok(self.insert_plan(name, crate::artifact::load_grimc(path)?))
    }

    /// Load every `*.grimc` in `dir` (model name = file stem), sorted for
    /// determinism, and remember `dir` as the artifact directory so
    /// models evicted (or added to the directory) later can come back
    /// via background loads. Returns the loaded names.
    pub fn load_dir(&self, dir: &Path) -> anyhow::Result<Vec<String>> {
        self.set_artifact_dir(dir);
        let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "grimc"))
            .collect();
        paths.sort();
        let mut names = Vec::with_capacity(paths.len());
        for p in paths {
            let name = p
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow::anyhow!("bad artifact file name {}", p.display()))?
                .to_string();
            self.load_file(name.clone(), &p)?;
            names.push(name);
        }
        Ok(names)
    }

    /// Look a model up, bumping its LRU recency.
    pub fn get(&self, name: &str) -> Option<Arc<Engine>> {
        let mut g = self.inner.lock().unwrap();
        let e = g.get_mut(name)?;
        e.last_used = self.clock.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(&e.engine))
    }

    /// Remove a model by name; returns whether it was present. The
    /// engine itself is torn down after the lock is released.
    pub fn evict(&self, name: &str) -> bool {
        let removed = { self.inner.lock().unwrap().remove(name) };
        removed.is_some()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes across registered models.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().values().map(|e| e.resident).sum()
    }

    /// The budget, or `None` when unlimited.
    pub fn budget_bytes(&self) -> Option<usize> {
        (self.budget != usize::MAX).then_some(self.budget)
    }

    /// Models evicted by the budget (not counting explicit [`Self::evict`]).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Per-model stats snapshot, sorted by name. The registry lock is
    /// held only to copy the entry list — per-model telemetry (pool
    /// stats, quotas, miss counts, each behind its own lock) is gathered
    /// afterwards so a stats scrape never stalls request routing.
    pub fn stats(&self) -> Vec<ModelStats> {
        let entries: Vec<(String, usize, Arc<Engine>)> = {
            let g = self.inner.lock().unwrap();
            g.iter()
                .map(|(name, e)| (name.clone(), e.resident, Arc::clone(&e.engine)))
                .collect()
        };
        let mut v: Vec<ModelStats> = entries
            .into_iter()
            .map(|(name, resident_bytes, engine)| ModelStats {
                pool: engine.workspace_pool().stats(),
                weight_bytes: engine.plan().weight_bytes_by_dtype(),
                quota: self.runtime.quota(&name),
                not_resident: self.not_resident(&name),
                name,
                resident_bytes,
            })
            .collect();
        // Misses against models that are NOT resident (never loaded, or
        // evicted) are the primary admission-control signal — surface
        // them as zero-resident rows instead of hiding them until the
        // model happens to load. Includes the "*" overflow bucket.
        let missed: Vec<(String, u64)> = {
            let g = self.misses.lock().unwrap();
            g.iter()
                .filter(|(name, _)| !v.iter().any(|m| &m.name == *name))
                .map(|(name, n)| (name.clone(), *n))
                .collect()
        };
        for (name, not_resident) in missed {
            v.push(ModelStats {
                quota: self.runtime.quota(&name),
                name,
                resident_bytes: 0,
                weight_bytes: [(crate::quant::DType::F32, 0), (crate::quant::DType::I8, 0)],
                pool: PoolStats::default(),
                not_resident,
            });
        }
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Append the registry's gauges and counters in Prometheus text
    /// format: one `grim_model_*` row per resident model (labelled
    /// `{model="..."}`), plus registry-level residency/budget/eviction
    /// totals. Families are grouped under one `# TYPE` line each, as the
    /// exposition format requires.
    pub fn render_prometheus_into(&self, out: &mut String) {
        use std::fmt::Write;
        let stats = self.stats();
        let mut family = |name: &str,
                          kind: &str,
                          rows: Vec<(String, String)>| {
            if rows.is_empty() {
                return;
            }
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for (model, value) in rows {
                let _ = writeln!(out, "{name}{{model=\"{model}\"}} {value}");
            }
        };
        family(
            "grim_model_resident_bytes",
            "gauge",
            stats.iter().map(|m| (m.name.clone(), m.resident_bytes.to_string())).collect(),
        );
        family(
            "grim_model_arena_bytes",
            "gauge",
            stats.iter().map(|m| (m.name.clone(), m.pool.arena_bytes.to_string())).collect(),
        );
        family(
            "grim_model_arenas",
            "gauge",
            stats.iter().map(|m| (m.name.clone(), m.pool.arenas_created.to_string())).collect(),
        );
        family(
            "grim_model_checkouts_total",
            "counter",
            stats.iter().map(|m| (m.name.clone(), m.pool.checkouts.to_string())).collect(),
        );
        family(
            "grim_model_quota_buckets",
            "gauge",
            stats
                .iter()
                .filter_map(|m| m.quota.map(|q| (m.name.clone(), q.to_string())))
                .collect(),
        );
        family(
            "grim_model_not_resident_total",
            "counter",
            stats
                .iter()
                .filter(|m| m.not_resident > 0)
                .map(|m| (m.name.clone(), m.not_resident.to_string()))
                .collect(),
        );
        // Per-model, per-dtype weight residency: the only two-label
        // family here, written directly (the `family` closure above is
        // single-label). `{dtype="i8"}` rows appearing at all means the
        // quantize pass took some layers; the f32/i8 byte split is the
        // quantization win per model.
        let dtype_rows: Vec<(String, &'static str, usize)> = stats
            .iter()
            .flat_map(|m| {
                m.weight_bytes
                    .iter()
                    .filter(|(_, bytes)| *bytes > 0)
                    .map(|(d, bytes)| (m.name.clone(), d.as_str(), *bytes))
                    .collect::<Vec<_>>()
            })
            .collect();
        if !dtype_rows.is_empty() {
            let _ = writeln!(out, "# TYPE grim_weight_bytes gauge");
            for (model, dtype, bytes) in dtype_rows {
                let _ =
                    writeln!(out, "grim_weight_bytes{{model=\"{model}\",dtype=\"{dtype}\"}} {bytes}");
            }
        }
        let _ = writeln!(out, "# TYPE grim_registry_resident_bytes gauge");
        let _ = writeln!(out, "grim_registry_resident_bytes {}", self.resident_bytes());
        if let Some(b) = self.budget_bytes() {
            let _ = writeln!(out, "# TYPE grim_registry_budget_bytes gauge");
            let _ = writeln!(out, "grim_registry_budget_bytes {b}");
        }
        let _ = writeln!(out, "# TYPE grim_registry_evictions_total counter");
        let _ = writeln!(out, "grim_registry_evictions_total {}", self.evictions());
        let _ = writeln!(out, "# TYPE grim_runtime_threads gauge");
        let _ = writeln!(out, "grim_runtime_threads {}", self.runtime.threads());
    }

    /// Evict least-recently-used models (never `keep`) until the total
    /// fits the budget. Removed entries are pushed to `dropped` so the
    /// caller can tear them down outside the registry lock.
    fn evict_over_budget(
        &self,
        g: &mut HashMap<String, Entry>,
        keep: &str,
        dropped: &mut Vec<Entry>,
    ) {
        loop {
            let total: usize = g.values().map(|e| e.resident).sum();
            if total <= self.budget || g.len() <= 1 {
                return;
            }
            let victim = g
                .iter()
                .filter(|(n, _)| n.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            match victim {
                Some(v) => {
                    if let Some(e) = g.remove(&v) {
                        dropped.push(e);
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // Only `keep` is left: over budget, but never evicted.
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn plan_for(kind: ModelKind, seed: u64) -> ExecutionPlan {
        let o = InitOptions { rate: 6.0, block: [4, 16], seed };
        let m = build_model(kind, Preset::CifarMini, o);
        let w = random_weights(&m, o);
        compile(&m, &w, CompileOptions::default()).unwrap()
    }

    fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
        let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
        Tensor::rand_uniform(&dims, 1.0, rng)
    }

    #[test]
    fn serves_two_models_with_isolated_pools() {
        let reg = ModelRegistry::new(2);
        reg.insert_plan("cnn", plan_for(ModelKind::Vgg16, 1));
        reg.insert_plan("rnn", plan_for(ModelKind::Gru, 2));
        assert_eq!(reg.names(), vec!["cnn".to_string(), "rnn".to_string()]);
        let cnn = reg.get("cnn").unwrap();
        let rnn = reg.get("rnn").unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..3 {
            cnn.run(&input_for(&cnn, &mut rng)).unwrap();
        }
        for _ in 0..5 {
            rnn.run(&input_for(&rnn, &mut rng)).unwrap();
        }
        let stats = reg.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "cnn");
        assert_eq!(stats[0].pool.checkouts, 3, "cnn pool counts only cnn requests");
        assert_eq!(stats[1].pool.checkouts, 5, "rnn pool counts only rnn requests");
        assert!(stats[0].resident_bytes > 0 && stats[1].resident_bytes > 0);
        assert_eq!(reg.resident_bytes(), stats[0].resident_bytes + stats[1].resident_bytes);
    }

    #[test]
    fn budget_evicts_least_recently_used() {
        let a = plan_for(ModelKind::Gru, 10);
        let one = plan_resident_bytes(&a);
        // Room for two models of this size, not three.
        let reg = ModelRegistry::with_budget(1, 2 * one + one / 2);
        reg.insert_plan("a", a);
        reg.insert_plan("b", plan_for(ModelKind::Gru, 11));
        assert_eq!(reg.len(), 2);
        // Touch "a" so "b" becomes the LRU victim.
        assert!(reg.get("a").is_some());
        reg.insert_plan("c", plan_for(ModelKind::Gru, 12));
        assert_eq!(reg.len(), 2, "third insert must evict one model");
        assert!(reg.get("b").is_none(), "least-recently-used model evicted");
        assert!(reg.get("a").is_some() && reg.get("c").is_some());
        assert_eq!(reg.evictions(), 1);
        assert!(reg.resident_bytes() <= reg.budget_bytes().unwrap());
    }

    #[test]
    fn single_over_budget_model_still_serves() {
        let plan = plan_for(ModelKind::Gru, 20);
        let reg = ModelRegistry::with_budget(1, 1); // absurdly small budget
        reg.insert_plan("only", plan);
        let e = reg.get("only").expect("sole model never evicted");
        let mut rng = Rng::new(4);
        e.run(&input_for(&e, &mut rng)).unwrap();
    }

    #[test]
    fn in_flight_handle_survives_eviction() {
        let reg = ModelRegistry::new(1);
        reg.insert_plan("m", plan_for(ModelKind::Gru, 30));
        let handle = reg.get("m").unwrap();
        assert!(reg.evict("m"));
        assert!(reg.get("m").is_none());
        // The held Arc keeps the engine alive and runnable.
        let mut rng = Rng::new(5);
        handle.run(&input_for(&handle, &mut rng)).unwrap();
    }

    #[test]
    fn engines_share_the_registry_runtime() {
        let reg = ModelRegistry::new(3);
        let a = reg.insert_plan("a", plan_for(ModelKind::Gru, 50));
        let b = reg.insert_plan("b", plan_for(ModelKind::Gru, 51));
        assert!(
            Arc::ptr_eq(&a.runtime(), &reg.runtime()) && Arc::ptr_eq(&b.runtime(), &reg.runtime()),
            "every registry engine must borrow the one shared runtime"
        );
        assert_eq!(a.threads(), 3);
        // Quota applies to the resident engine as a schedule rebalance.
        assert_eq!(reg.set_quota("a", 2), 2);
        assert_eq!(a.schedules().threads, 2);
        assert_eq!(b.schedules().threads, 3, "other models keep the full width");
        // A model inserted after its quota was set picks it up.
        reg.set_quota("c", 1);
        let c = reg.insert_plan("c", plan_for(ModelKind::Gru, 52));
        assert_eq!(c.schedules().threads, 1);
        reg.clear_quota("a");
        assert_eq!(a.schedules().threads, 3);
    }

    #[test]
    fn miss_counter_and_policy_survive_eviction() {
        let reg = ModelRegistry::new(1);
        let policy = crate::coordinator::BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(0),
        };
        reg.set_policy("m", policy);
        reg.note_miss("m");
        reg.insert_plan("m", plan_for(ModelKind::Gru, 60));
        assert!(reg.evict("m"));
        reg.note_miss("m");
        assert_eq!(reg.not_resident("m"), 2);
        assert_eq!(reg.policy_for("m").map(|p| p.max_batch), Some(1));
    }

    /// The Prometheus rendering covers every resident model and parses
    /// back with the crate's own minimal parser.
    #[test]
    fn prometheus_rows_cover_resident_models() {
        let reg = ModelRegistry::new(1);
        reg.insert_plan("m", plan_for(ModelKind::Gru, 70));
        let e = reg.get("m").unwrap();
        let mut rng = Rng::new(6);
        e.run(&input_for(&e, &mut rng)).unwrap();
        // A second model compiled with --dtype i8 must surface per-dtype
        // weight rows: i8 bytes for its packed layers, f32 for the rest.
        let o = InitOptions { rate: 6.0, block: [4, 16], seed: 71 };
        let m = build_model(ModelKind::Gru, Preset::CifarMini, o);
        let w = random_weights(&m, o);
        let copts = CompileOptions { dtype: crate::quant::DType::I8, ..Default::default() };
        reg.insert_plan("q", compile(&m, &w, copts).unwrap());
        let mut out = String::new();
        reg.render_prometheus_into(&mut out);
        assert!(out.contains("grim_model_resident_bytes{model=\"m\"}"));
        assert!(out.contains("grim_weight_bytes{model=\"m\",dtype=\"f32\"}"));
        assert!(
            out.contains("grim_weight_bytes{model=\"q\",dtype=\"i8\"}"),
            "quantized model must report i8 weight bytes:\n{out}"
        );
        let samples = crate::obs::parse_text(&out).unwrap();
        let i8_row = samples
            .iter()
            .find(|s| s.name == "grim_weight_bytes" && s.label("dtype") == Some("i8"))
            .unwrap();
        assert!(i8_row.value > 0.0);
        let threads = samples.iter().find(|s| s.name == "grim_runtime_threads").unwrap();
        assert_eq!(threads.value, 1.0);
        let checkouts = samples
            .iter()
            .find(|s| s.name == "grim_model_checkouts_total")
            .unwrap();
        assert_eq!(checkouts.label("model"), Some("m"));
        assert!(checkouts.value >= 1.0);
    }

    #[test]
    fn replacing_a_name_keeps_registry_consistent() {
        let reg = ModelRegistry::new(1);
        reg.insert_plan("m", plan_for(ModelKind::Gru, 40));
        let first = reg.get("m").unwrap();
        reg.insert_plan("m", plan_for(ModelKind::Gru, 41));
        assert_eq!(reg.len(), 1, "re-inserting a name replaces, never duplicates");
        let second = reg.get("m").unwrap();
        assert!(!Arc::ptr_eq(&first, &second), "replacement installs the new engine");
    }
}
