//! The model registry: named engines, hot-loaded from `.grimc`
//! artifacts, with per-model workspace pools and a resident-bytes LRU
//! eviction budget.
//!
//! Design notes:
//!
//! * **Isolation** — every model gets its own [`Engine`], which owns its
//!   own [`crate::memory::WorkspacePool`] (arenas sized to *that* plan)
//!   and worker pool. One model's traffic can never corrupt or observe
//!   another's arenas; per-model stats come straight from the pool.
//! * **Hot loading** — the registry is shared behind an `Arc`; models can
//!   be inserted or evicted while a
//!   [`crate::coordinator::Server`] is routing requests over it. The
//!   scheduler resolves names at execution time, so a request for an
//!   evicted model fails with a clear error instead of silently pinning
//!   the engine's memory.
//! * **Budget** — `resident bytes` per model = weight storage + packed
//!   buffers + one workspace arena ([`plan_resident_bytes`]). When an
//!   insert pushes the total over the budget, least-recently-*used*
//!   models (both `get` and insert bump recency) are evicted until it
//!   fits; the incoming model itself is never evicted, so a single
//!   over-budget model still serves (better than serving nothing).
//!   In-flight requests holding the evicted `Arc<Engine>` finish
//!   normally; the memory is freed when the last handle drops.

use crate::compiler::plan::ExecutionPlan;
use crate::engine::Engine;
use crate::memory::PoolStats;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Bytes a loaded model keeps resident: weight storage (dense tensors or
/// sparse encodings), the packed weight buffers the packing pass added,
/// and one workspace arena (steady-state single-stream serving; each
/// additional concurrent request adds one arena).
pub fn plan_resident_bytes(plan: &ExecutionPlan) -> usize {
    plan.storage_bytes() + plan.packing.packed_bytes + plan.memory.arena_bytes()
}

struct Entry {
    engine: Arc<Engine>,
    resident: usize,
    last_used: u64,
}

/// Per-model stats snapshot (see [`ModelRegistry::stats`]).
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    /// Weights + packed buffers + one arena, in bytes.
    pub resident_bytes: usize,
    /// This model's isolated workspace-pool telemetry; `checkouts` is the
    /// number of inferences the model has served.
    pub pool: PoolStats,
}

/// Named-model registry with LRU eviction under a resident-bytes budget.
pub struct ModelRegistry {
    /// Worker threads per model engine.
    threads: usize,
    /// Resident-bytes ceiling (`usize::MAX` = unlimited).
    budget: usize,
    inner: Mutex<HashMap<String, Entry>>,
    /// Logical LRU clock (bumped on every insert and `get`).
    clock: AtomicU64,
    evictions: AtomicU64,
}

impl ModelRegistry {
    /// Registry without a resident-bytes budget.
    pub fn new(threads: usize) -> Self {
        Self::with_budget(threads, usize::MAX)
    }

    /// Registry enforcing `budget_bytes` of total model residency.
    pub fn with_budget(threads: usize, budget_bytes: usize) -> Self {
        ModelRegistry {
            threads: threads.max(1),
            budget: budget_bytes.max(1),
            inner: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Register an already-built engine under `name` (replacing any
    /// previous model of that name), then evict LRU models while over
    /// budget. Returns the shared engine handle.
    pub fn insert_engine(&self, name: impl Into<String>, engine: Engine) -> Arc<Engine> {
        let name = name.into();
        let resident = plan_resident_bytes(engine.plan());
        let engine = Arc::new(engine);
        // Entries removed under the lock are torn down *after* it is
        // released: dropping an Engine joins its worker pool and frees
        // its buffers, which must not stall concurrent request routing.
        let mut dropped: Vec<Entry> = Vec::new();
        {
            let mut g = self.inner.lock().unwrap();
            let last_used = self.tick();
            if let Some(old) =
                g.insert(name.clone(), Entry { engine: Arc::clone(&engine), resident, last_used })
            {
                dropped.push(old);
            }
            self.evict_over_budget(&mut g, &name, &mut dropped);
        }
        drop(dropped);
        engine
    }

    /// Build an engine for `plan` (with this registry's thread count) and
    /// register it.
    pub fn insert_plan(&self, name: impl Into<String>, plan: ExecutionPlan) -> Arc<Engine> {
        self.insert_engine(name, Engine::new(plan, self.threads))
    }

    /// Hot-load a `.grimc` artifact as model `name` — the full AOT path:
    /// no graph compilation, no BCR re-encoding, no re-packing.
    pub fn load_file(&self, name: impl Into<String>, path: &Path) -> anyhow::Result<Arc<Engine>> {
        Ok(self.insert_plan(name, crate::artifact::load_grimc(path)?))
    }

    /// Load every `*.grimc` in `dir` (model name = file stem), sorted for
    /// determinism. Returns the loaded names.
    pub fn load_dir(&self, dir: &Path) -> anyhow::Result<Vec<String>> {
        let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "grimc"))
            .collect();
        paths.sort();
        let mut names = Vec::with_capacity(paths.len());
        for p in paths {
            let name = p
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow::anyhow!("bad artifact file name {}", p.display()))?
                .to_string();
            self.load_file(name.clone(), &p)?;
            names.push(name);
        }
        Ok(names)
    }

    /// Look a model up, bumping its LRU recency.
    pub fn get(&self, name: &str) -> Option<Arc<Engine>> {
        let mut g = self.inner.lock().unwrap();
        let e = g.get_mut(name)?;
        e.last_used = self.clock.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(&e.engine))
    }

    /// Remove a model by name; returns whether it was present. The
    /// engine itself is torn down after the lock is released.
    pub fn evict(&self, name: &str) -> bool {
        let removed = { self.inner.lock().unwrap().remove(name) };
        removed.is_some()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total resident bytes across registered models.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().values().map(|e| e.resident).sum()
    }

    /// The budget, or `None` when unlimited.
    pub fn budget_bytes(&self) -> Option<usize> {
        (self.budget != usize::MAX).then_some(self.budget)
    }

    /// Models evicted by the budget (not counting explicit [`Self::evict`]).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Per-model stats snapshot, sorted by name.
    pub fn stats(&self) -> Vec<ModelStats> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<ModelStats> = g
            .iter()
            .map(|(name, e)| ModelStats {
                name: name.clone(),
                resident_bytes: e.resident,
                pool: e.engine.workspace_pool().stats(),
            })
            .collect();
        drop(g);
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Evict least-recently-used models (never `keep`) until the total
    /// fits the budget. Removed entries are pushed to `dropped` so the
    /// caller can tear them down outside the registry lock.
    fn evict_over_budget(
        &self,
        g: &mut HashMap<String, Entry>,
        keep: &str,
        dropped: &mut Vec<Entry>,
    ) {
        loop {
            let total: usize = g.values().map(|e| e.resident).sum();
            if total <= self.budget || g.len() <= 1 {
                return;
            }
            let victim = g
                .iter()
                .filter(|(n, _)| n.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            match victim {
                Some(v) => {
                    if let Some(e) = g.remove(&v) {
                        dropped.push(e);
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // Only `keep` is left: over budget, but never evicted.
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, CompileOptions};
    use crate::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn plan_for(kind: ModelKind, seed: u64) -> ExecutionPlan {
        let o = InitOptions { rate: 6.0, block: [4, 16], seed };
        let m = build_model(kind, Preset::CifarMini, o);
        let w = random_weights(&m, o);
        compile(&m, &w, CompileOptions::default()).unwrap()
    }

    fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
        let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
        Tensor::rand_uniform(&dims, 1.0, rng)
    }

    #[test]
    fn serves_two_models_with_isolated_pools() {
        let reg = ModelRegistry::new(2);
        reg.insert_plan("cnn", plan_for(ModelKind::Vgg16, 1));
        reg.insert_plan("rnn", plan_for(ModelKind::Gru, 2));
        assert_eq!(reg.names(), vec!["cnn".to_string(), "rnn".to_string()]);
        let cnn = reg.get("cnn").unwrap();
        let rnn = reg.get("rnn").unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..3 {
            cnn.run(&input_for(&cnn, &mut rng)).unwrap();
        }
        for _ in 0..5 {
            rnn.run(&input_for(&rnn, &mut rng)).unwrap();
        }
        let stats = reg.stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "cnn");
        assert_eq!(stats[0].pool.checkouts, 3, "cnn pool counts only cnn requests");
        assert_eq!(stats[1].pool.checkouts, 5, "rnn pool counts only rnn requests");
        assert!(stats[0].resident_bytes > 0 && stats[1].resident_bytes > 0);
        assert_eq!(reg.resident_bytes(), stats[0].resident_bytes + stats[1].resident_bytes);
    }

    #[test]
    fn budget_evicts_least_recently_used() {
        let a = plan_for(ModelKind::Gru, 10);
        let one = plan_resident_bytes(&a);
        // Room for two models of this size, not three.
        let reg = ModelRegistry::with_budget(1, 2 * one + one / 2);
        reg.insert_plan("a", a);
        reg.insert_plan("b", plan_for(ModelKind::Gru, 11));
        assert_eq!(reg.len(), 2);
        // Touch "a" so "b" becomes the LRU victim.
        assert!(reg.get("a").is_some());
        reg.insert_plan("c", plan_for(ModelKind::Gru, 12));
        assert_eq!(reg.len(), 2, "third insert must evict one model");
        assert!(reg.get("b").is_none(), "least-recently-used model evicted");
        assert!(reg.get("a").is_some() && reg.get("c").is_some());
        assert_eq!(reg.evictions(), 1);
        assert!(reg.resident_bytes() <= reg.budget_bytes().unwrap());
    }

    #[test]
    fn single_over_budget_model_still_serves() {
        let plan = plan_for(ModelKind::Gru, 20);
        let reg = ModelRegistry::with_budget(1, 1); // absurdly small budget
        reg.insert_plan("only", plan);
        let e = reg.get("only").expect("sole model never evicted");
        let mut rng = Rng::new(4);
        e.run(&input_for(&e, &mut rng)).unwrap();
    }

    #[test]
    fn in_flight_handle_survives_eviction() {
        let reg = ModelRegistry::new(1);
        reg.insert_plan("m", plan_for(ModelKind::Gru, 30));
        let handle = reg.get("m").unwrap();
        assert!(reg.evict("m"));
        assert!(reg.get("m").is_none());
        // The held Arc keeps the engine alive and runnable.
        let mut rng = Rng::new(5);
        handle.run(&input_for(&handle, &mut rng)).unwrap();
    }

    #[test]
    fn replacing_a_name_keeps_registry_consistent() {
        let reg = ModelRegistry::new(1);
        reg.insert_plan("m", plan_for(ModelKind::Gru, 40));
        let first = reg.get("m").unwrap();
        reg.insert_plan("m", plan_for(ModelKind::Gru, 41));
        assert_eq!(reg.len(), 1, "re-inserting a name replaces, never duplicates");
        let second = reg.get("m").unwrap();
        assert!(!Arc::ptr_eq(&first, &second), "replacement installs the new engine");
    }
}
