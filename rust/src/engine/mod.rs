//! The inference engine: interprets an [`crate::compiler::ExecutionPlan`]
//! over a worker pool with per-layer metrics.

pub mod executor;
pub mod metrics;

pub use executor::Engine;
pub use metrics::{LayerMetric, RunMetrics};
