//! Plan interpreter. Each [`Step`] dispatches to the kernel its
//! [`KernelImpl`] selected at compile time; GEMMs above a size threshold
//! run on the worker pool (the "8 threads on CPU" of §6.1).

use crate::compiler::plan::{Activation, ExecutionPlan, GruLayerPlan, KernelImpl, Step};
use crate::conv::direct::depthwise_conv2d_parallel;
use crate::conv::im2col::{im2col, im2col_skip, ConvGeom};
use crate::conv::ops;
use crate::conv::winograd::conv2d_winograd;
use crate::gemm::csr_gemm::{csr_gemm, csr_gemm_parallel};
use crate::gemm::naive::naive_gemm_dense;
use crate::gemm::tiled::{tiled_gemm, tiled_gemm_parallel};
use crate::tensor::Tensor;
use crate::util::{ThreadPool, Timer};

use super::metrics::{LayerMetric, RunMetrics};

/// Minimum GEMM output elements before the parallel path is used; below
/// this the dispatch overhead dominates.
const PARALLEL_THRESHOLD: usize = 16 * 1024;

/// The inference engine: a plan bound to a worker pool.
pub struct Engine {
    plan: ExecutionPlan,
    pool: ThreadPool,
    /// Collect per-layer metrics (small overhead; off on the serving path).
    pub collect_metrics: bool,
}

impl Engine {
    pub fn new(plan: ExecutionPlan, threads: usize) -> Self {
        Engine { plan, pool: ThreadPool::new(threads.max(1)), collect_metrics: false }
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Run one inference; returns the output tensor.
    pub fn run(&self, input: &Tensor) -> anyhow::Result<Tensor> {
        Ok(self.run_with_metrics(input)?.0)
    }

    /// Run one inference, returning output + per-layer metrics.
    pub fn run_with_metrics(&self, input: &Tensor) -> anyhow::Result<(Tensor, RunMetrics)> {
        let n = self.plan.steps.len();
        let mut values: Vec<Option<Tensor>> = vec![None; n];
        let mut metrics = RunMetrics::default();
        for (id, step) in &self.plan.steps {
            let t = Timer::start();
            let kind = self.exec_step(*id, step, input, &mut values)?;
            if self.collect_metrics {
                metrics.layers.push(LayerMetric { node: *id, kind, micros: t.elapsed_us() });
            }
        }
        let out = values[self.plan.output_id]
            .take()
            .ok_or_else(|| anyhow::anyhow!("output not produced"))?;
        Ok((out, metrics))
    }

    fn value<'a>(
        &self,
        values: &'a [Option<Tensor>],
        id: usize,
        slot: usize,
    ) -> anyhow::Result<&'a Tensor> {
        let src = self.plan.inputs[id]
            .get(slot)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("node {id}: missing input {slot}"))?;
        values[src].as_ref().ok_or_else(|| anyhow::anyhow!("node {id}: input {src} not computed"))
    }

    fn exec_step(
        &self,
        id: usize,
        step: &Step,
        input: &Tensor,
        values: &mut Vec<Option<Tensor>>,
    ) -> anyhow::Result<&'static str> {
        let kind: &'static str;
        let out = match step {
            Step::Input => {
                kind = "input";
                Some(input.clone())
            }
            Step::Conv { geom, kernel, dead_cols, bias, act } => {
                kind = "conv";
                let x = self.value(values, id, 0)?;
                let out = self.exec_conv(geom, kernel, dead_cols.as_deref(), x)?;
                let mut out = out.reshape(&[geom.out_c, geom.out_h(), geom.out_w()]);
                ops::add_bias_(&mut out, bias);
                apply_act(&mut out, *act);
                Some(out)
            }
            Step::DwConv { kh: _, kw: _, stride, pad, w, bias, act } => {
                kind = "dwconv";
                let x = self.value(values, id, 0)?;
                let mut out = depthwise_conv2d_parallel(x, w, *stride, *pad, &self.pool);
                ops::add_bias_(&mut out, bias);
                apply_act(&mut out, *act);
                Some(out)
            }
            Step::Fc { kernel, bias, act } => {
                kind = "fc";
                let x = self.value(values, id, 0)?;
                let xin = x.clone().reshape(&[x.numel(), 1]);
                let mut out = self.exec_gemm(kernel, &xin)?;
                let rows = out.shape().dim(0);
                out = out.reshape(&[rows]);
                for (o, b) in out.data_mut().iter_mut().zip(bias.iter()) {
                    *o += b;
                }
                apply_act(&mut out, *act);
                Some(out)
            }
            Step::Gru { layers } => {
                kind = "gru";
                let x = self.value(values, id, 0)?;
                Some(self.exec_gru(layers, x)?)
            }
            Step::MaxPool2 => {
                kind = "maxpool";
                Some(ops::maxpool2(self.value(values, id, 0)?))
            }
            Step::GlobalAvgPool => {
                kind = "gap";
                Some(ops::global_avgpool(self.value(values, id, 0)?))
            }
            Step::Relu => {
                kind = "relu";
                let mut v = self.value(values, id, 0)?.clone();
                ops::relu_(&mut v);
                Some(v)
            }
            Step::Relu6 => {
                kind = "relu6";
                let mut v = self.value(values, id, 0)?.clone();
                ops::relu6_(&mut v);
                Some(v)
            }
            Step::Add => {
                kind = "add";
                let mut a = self.value(values, id, 0)?.clone();
                let b = self.value(values, id, 1)?;
                ops::add_(&mut a, b);
                Some(a)
            }
            Step::Flatten => {
                kind = "flatten";
                let v = self.value(values, id, 0)?.clone();
                let n = v.numel();
                Some(v.reshape(&[n]))
            }
            Step::Softmax => {
                kind = "softmax";
                let v = self.value(values, id, 0)?;
                let n = v.numel();
                Some(ops::softmax_rows(&v.clone().reshape(&[1, n]), n).reshape(&[n]))
            }
            Step::Noop => {
                // fused away; consumers were redirected at compile time
                kind = "noop";
                None
            }
        };
        values[id] = out;
        Ok(kind)
    }

    fn exec_conv(
        &self,
        geom: &ConvGeom,
        kernel: &KernelImpl,
        dead: Option<&Vec<bool>>,
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        // Winograd bypasses im2col entirely.
        if let KernelImpl::Winograd { w4 } = kernel {
            return Ok(conv2d_winograd(x, w4, geom.pad));
        }
        // 1x1 stride-1 convs: im2col is the identity — feed x directly
        // ([C,H,W] viewed as [C, H*W]); MobileNet is mostly this case.
        if geom.kh == 1 && geom.kw == 1 && geom.stride == 1 && geom.pad == 0 {
            let cols = x.clone().reshape(&[geom.in_c, geom.in_h * geom.in_w]);
            return self.exec_gemm(kernel, &cols);
        }
        let cols = match dead {
            Some(d) => im2col_skip(x, geom, d),
            None => im2col(x, geom),
        };
        self.exec_gemm(kernel, &cols)
    }

    fn exec_gemm(&self, kernel: &KernelImpl, x: &Tensor) -> anyhow::Result<Tensor> {
        let (_, n) = x.shape().as_matrix();
        Ok(match kernel {
            KernelImpl::NaiveDense { w } => naive_gemm_dense(w, x), // honest dense: no zero skip
            KernelImpl::Dense { w, params } => {
                let (m, _) = w.shape().as_matrix();
                if m * n >= PARALLEL_THRESHOLD {
                    tiled_gemm_parallel(w, x, *params, &self.pool)
                } else {
                    tiled_gemm(w, x, *params)
                }
            }
            KernelImpl::Winograd { .. } => anyhow::bail!("winograd outside conv"),
            KernelImpl::Csr { mat } => {
                if mat.rows * n >= PARALLEL_THRESHOLD {
                    csr_gemm_parallel(mat, x, &self.pool)
                } else {
                    csr_gemm(mat, x)
                }
            }
            KernelImpl::Bcrc { gemm } => {
                if gemm.enc.rows * n >= PARALLEL_THRESHOLD {
                    gemm.execute_parallel(x, &self.pool)
                } else {
                    gemm.execute(x)
                }
            }
        })
    }

    /// Stacked GRU over a `[T, in_f]` sequence; returns `[T, hidden]` of
    /// the last layer.
    fn exec_gru(&self, layers: &[GruLayerPlan], x: &Tensor) -> anyhow::Result<Tensor> {
        let (t_len, mut in_f) = x.shape().as_matrix();
        let mut seq = x.clone();
        for layer in layers {
            anyhow::ensure!(in_f == layer.in_f, "gru input width mismatch");
            let h = layer.hidden;
            let mut hidden = vec![0.0f32; h];
            let mut out_seq = Tensor::zeros(&[t_len, h]);
            let mut cat = vec![0.0f32; in_f + h];
            for t in 0..t_len {
                let xt = &seq.data()[t * in_f..(t + 1) * in_f];
                cat[..in_f].copy_from_slice(xt);
                cat[in_f..].copy_from_slice(&hidden);
                let cat_t = Tensor::from_vec(&[in_f + h, 1], cat.clone());
                let z = self.gate(&layer.wz, &cat_t, &layer.bz, true)?;
                let r = self.gate(&layer.wr, &cat_t, &layer.br, true)?;
                // candidate uses [x, r ⊙ h]
                let mut cat2 = cat.clone();
                for i in 0..h {
                    cat2[in_f + i] = r[i] * hidden[i];
                }
                let cat2_t = Tensor::from_vec(&[in_f + h, 1], cat2);
                let hc = self.gate(&layer.wh, &cat2_t, &layer.bh, false)?;
                for i in 0..h {
                    hidden[i] = (1.0 - z[i]) * hidden[i] + z[i] * hc[i];
                }
                out_seq.data_mut()[t * h..(t + 1) * h].copy_from_slice(&hidden);
            }
            seq = out_seq;
            in_f = h;
        }
        Ok(seq)
    }

    fn gate(
        &self,
        kernel: &KernelImpl,
        x: &Tensor,
        bias: &[f32],
        sigmoid: bool,
    ) -> anyhow::Result<Vec<f32>> {
        let mut v = self.exec_gemm(kernel, x)?.into_vec();
        for (o, b) in v.iter_mut().zip(bias) {
            *o += b;
            *o = if sigmoid { 1.0 / (1.0 + (-*o).exp()) } else { o.tanh() };
        }
        Ok(v)
    }
}

fn apply_act(x: &mut Tensor, act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => ops::relu_(x),
        Activation::Relu6 => ops::relu6_(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, Backend, CompileOptions};
    use crate::compiler::weights::{gru_key, LayerWeights, WeightStore};
    use crate::graph::dsl;
    use crate::sparse::{BcrConfig, BcrMask};
    use crate::util::Rng;
    use std::collections::HashMap;

    fn cnn_module() -> dsl::Module {
        dsl::parse(
            r#"
model "tiny"
in = Input(shape=[3,8,8])
c1 = Conv2D(in, out_c=8, kh=3, kw=3, stride=1, pad=1)
r1 = ReLU(c1)
p1 = MaxPool2(r1)
f = Flatten(p1)
fc1 = FC(f, out_f=10)
out = Softmax(fc1)
@ir c1 { block_size=[2,9]; rate=3.0 }
@ir fc1 { block_size=[2,16]; rate=2.0 }
"#,
        )
        .unwrap()
    }

    fn cnn_weights(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut s = HashMap::new();
        let m1 = BcrMask::random(8, 27, BcrConfig::from_block_size(8, 27, 2, 9), 3.0, &mut rng);
        let mut w1 = Tensor::rand_uniform(&[8, 27], 0.5, &mut rng);
        m1.apply(&mut w1);
        s.insert("c1".into(), LayerWeights::dense(w1).with_mask(m1).with_bias(vec![0.1; 8]));
        let m2 = BcrMask::random(10, 128, BcrConfig::from_block_size(10, 128, 2, 16), 2.0, &mut rng);
        let mut w2 = Tensor::rand_uniform(&[10, 128], 0.5, &mut rng);
        m2.apply(&mut w2);
        s.insert("fc1".into(), LayerWeights::dense(w2).with_mask(m2));
        s
    }

    /// All four backends must produce identical outputs on the same
    /// (masked) weights — the cross-backend correctness property that
    /// anchors every speedup claim in the benches.
    #[test]
    fn backends_agree() {
        let m = cnn_module();
        let w = cnn_weights(1);
        let mut rng = Rng::new(42);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let mut outputs = Vec::new();
        for b in [Backend::Grim, Backend::NaiveDense, Backend::OptDense, Backend::CsrSparse] {
            let plan = compile(&m, &w, CompileOptions::for_backend(b)).unwrap();
            let engine = Engine::new(plan, 2);
            outputs.push((b, engine.run(&x).unwrap()));
        }
        let (b0, ref0) = &outputs[0];
        for (b, o) in &outputs[1..] {
            assert!(
                o.allclose(ref0, 1e-3, 1e-3),
                "{b:?} disagrees with {b0:?}: maxdiff={}",
                o.max_abs_diff(ref0)
            );
        }
    }

    #[test]
    fn softmax_output_sums_to_one() {
        let m = cnn_module();
        let w = cnn_weights(2);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let engine = Engine::new(plan, 1);
        let mut rng = Rng::new(7);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let out = engine.run(&x).unwrap();
        assert_eq!(out.numel(), 10);
        let sum: f32 = out.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }

    #[test]
    fn metrics_collected() {
        let m = cnn_module();
        let w = cnn_weights(3);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let mut engine = Engine::new(plan, 1);
        engine.collect_metrics = true;
        let mut rng = Rng::new(8);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let (_, metrics) = engine.run_with_metrics(&x).unwrap();
        assert_eq!(metrics.layers.len(), 7);
        assert!(metrics.total_micros() > 0.0);
    }

    fn gru_module() -> dsl::Module {
        dsl::parse(
            r#"
model "gru"
x = Input(shape=[5,12])
g = GRU(x, hidden=16, layers=2)
@ir g { block_size=[4,4]; rate=2.0 }
"#,
        )
        .unwrap()
    }

    fn gru_weights(seed: u64, sparse: bool) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut s = HashMap::new();
        let mut in_f = 12usize;
        for l in 0..2 {
            for gate in ['z', 'r', 'h'] {
                let cols = in_f + 16;
                let mut w = Tensor::rand_uniform(&[16, cols], 0.4, &mut rng);
                let lw = if sparse {
                    let mask =
                        BcrMask::random(16, cols, BcrConfig::from_block_size(16, cols, 4, 4), 2.0, &mut rng);
                    mask.apply(&mut w);
                    LayerWeights::dense(w).with_mask(mask)
                } else {
                    LayerWeights::dense(w)
                };
                s.insert(gru_key("g", l, gate), lw);
            }
            in_f = 16;
        }
        s
    }

    #[test]
    fn gru_backends_agree() {
        let m = gru_module();
        let w = gru_weights(5, true);
        let mut rng = Rng::new(9);
        let x = Tensor::rand_uniform(&[5, 12], 1.0, &mut rng);
        let grim = Engine::new(compile(&m, &w, CompileOptions::default()).unwrap(), 1);
        let dense = Engine::new(
            compile(&m, &w, CompileOptions::for_backend(Backend::NaiveDense)).unwrap(),
            1,
        );
        let a = grim.run(&x).unwrap();
        let b = dense.run(&x).unwrap();
        assert_eq!(a.shape().dims(), &[5, 16]);
        assert!(a.allclose(&b, 1e-4, 1e-4), "maxdiff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn gru_hidden_bounded() {
        // dense weights -> module without a BCRC IR pragma
        let m = dsl::parse("model \"gru\"\nx = Input(shape=[5,12])\ng = GRU(x, hidden=16, layers=2)")
            .unwrap();
        let w = gru_weights(6, false);
        let engine = Engine::new(compile(&m, &w, CompileOptions::default()).unwrap(), 1);
        let mut rng = Rng::new(10);
        let x = Tensor::rand_uniform(&[5, 12], 2.0, &mut rng);
        let out = engine.run(&x).unwrap();
        // GRU hidden state is a convex combination of tanh outputs => |h| <= 1
        assert!(out.data().iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }
}
