//! Plan executor. Each [`Step`] dispatches to the kernel its
//! [`KernelImpl`] selected at compile time; GEMMs above a size threshold
//! run on the worker pool (the "8 threads on CPU" of §6.1).
//!
//! Two execution paths share every kernel and therefore compute
//! bit-identical results:
//!
//! * **planned** ([`Engine::run`]) — the serving path. All intermediates
//!   and scratch live at offsets assigned by the compile-time
//!   [`crate::memory::MemoryPlan`]; the run checks one arena out of the
//!   engine's [`WorkspacePool`] and performs *no per-step heap
//!   allocation* (including the Winograd baseline, whose kernel
//!   transforms are precomputed at compile time and whose per-tile
//!   scratch is planned into the arena like im2col).
//! * **naive** ([`Engine::run_naive`]) — the original interpreter holding
//!   each intermediate as an owned [`Tensor`]. Kept as the correctness
//!   reference the planned path is property-tested against.

use crate::compiler::packing::rebalance_partitions;
use crate::compiler::plan::{
    step_weight_bytes, Activation, ExecutionPlan, GruLayerPlan, KernelImpl, ScheduleSet, Step,
};
use crate::conv::direct::{depthwise_conv2d_into_ep, depthwise_conv2d_parallel_ep};
use crate::conv::im2col::{im2col, im2col_into, im2col_skip, ConvGeom};
use crate::conv::ops;
use crate::conv::winograd::{conv2d_winograd, conv2d_winograd_into};
use crate::exec::Runtime;
use crate::gemm::csr_gemm::{
    csr_gemm_into_ep, csr_gemm_parallel_into_ep, csr_gemm_partitioned_into_ep,
};
use crate::gemm::naive::naive_gemm_dense_into_ep;
use crate::gemm::simd::{self, Microkernels};
use crate::gemm::tiled::{
    tiled_gemm_into_ep, tiled_gemm_packed_into_ep, tiled_gemm_packed_parallel_into_ep,
    tiled_gemm_parallel_into_ep,
};
use crate::gemm::Epilogue;
use crate::memory::layout::{self, ConvScratch, GruScratch};
use crate::memory::{Workspace, WorkspacePool};
use crate::tensor::Tensor;
use crate::obs::trace;
use crate::util::{ThreadPool, Timer};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use super::metrics::{LayerMetric, RunMetrics};

/// Minimum GEMM output elements before the parallel path is used; below
/// this the dispatch overhead dominates.
const PARALLEL_THRESHOLD: usize = 16 * 1024;

/// The inference engine: a plan bound to a (usually shared) execution
/// [`Runtime`], a workspace arena pool, and the micro-kernel vtable
/// selected at startup.
///
/// The plan itself is **immutable** after construction — in particular,
/// the packed weight `Arc`s are never uniquely borrowed. The engine's
/// only mutable state besides the arena pool is its active
/// [`ScheduleSet`]: a rebalanced copy of the plan's compile-time
/// schedules, swapped atomically (behind an `RwLock<Arc<_>>`, read once
/// per inference) when the runtime quota changes.
pub struct Engine {
    plan: ExecutionPlan,
    /// The execution runtime this engine dispatches on. Registry engines
    /// share one process-wide runtime; `Engine::with_threads` builds a
    /// private one for standalone use.
    rt: Arc<Runtime>,
    /// Active parallel schedules, rebalanced to the runtime quota.
    /// Snapshot-per-run: each inference clones the `Arc` once.
    sched: RwLock<Arc<ScheduleSet>>,
    workspaces: Arc<WorkspacePool>,
    /// Micro-kernel table every GEMM/conv step runs on (CPU-dispatched at
    /// construction; individual BCRC layers can still pin themselves to
    /// scalar via `GemmParams::simd = false`).
    mk: &'static Microkernels,
    /// Weight bytes each step streams, precomputed so metrics collection
    /// costs an indexed load per step (parallel to `plan.steps`).
    step_bytes: Vec<usize>,
    /// Interned trace id of the plan's model name; 0 until the first
    /// sampled run resolves it (engines are usually built before tracing
    /// is enabled, so this cannot be interned eagerly).
    trace_model: AtomicU32,
    /// Collect per-layer metrics (small overhead; the registry turns it
    /// on for served engines so step-time histograms can be fed).
    pub collect_metrics: bool,
}

impl Engine {
    /// Engine over a **private** runtime of `threads` workers (alias of
    /// [`Self::with_threads`], kept as the historical constructor).
    pub fn new(plan: ExecutionPlan, threads: usize) -> Self {
        Self::with_threads(plan, threads)
    }

    /// Build an engine that owns a private `threads`-worker [`Runtime`].
    /// Standalone tools and tests use this; the serving tier shares one
    /// process-wide runtime via [`Self::with_runtime`] instead.
    pub fn with_threads(plan: ExecutionPlan, threads: usize) -> Self {
        let rt = Runtime::new(threads);
        let buckets = rt.threads();
        Self::with_runtime_mk(plan, rt, buckets, simd::active())
    }

    /// Build an engine pinned to a specific micro-kernel table — pass
    /// [`simd::scalar`] to force the scalar backend (testing/ablation).
    pub fn with_microkernels(
        plan: ExecutionPlan,
        threads: usize,
        mk: &'static Microkernels,
    ) -> Self {
        let rt = Runtime::new(threads);
        let buckets = rt.threads();
        Self::with_runtime_mk(plan, rt, buckets, mk)
    }

    /// Build an engine that **borrows** a shared runtime instead of
    /// spawning its own workers. N engines on one runtime keep the
    /// process at exactly the runtime's thread count. Schedules are
    /// balanced to the full pool width; fair-share quotas are keyed by
    /// *registry* model name (not the plan's internal name, which can
    /// collide across hot-load aliases), so the registry applies them
    /// via [`Self::rebalance`] once the model is registered.
    pub fn with_runtime(plan: ExecutionPlan, rt: Arc<Runtime>) -> Self {
        let buckets = rt.threads();
        Self::with_runtime_buckets(plan, rt, buckets)
    }

    /// [`Self::with_runtime`] balancing the schedules to `buckets`
    /// directly (the registry passes the model's quota here, so a
    /// quota'd load builds its schedules exactly once instead of
    /// pool-width-then-rebalance).
    pub fn with_runtime_buckets(plan: ExecutionPlan, rt: Arc<Runtime>, buckets: usize) -> Self {
        Self::with_runtime_mk(plan, rt, buckets, simd::active())
    }

    /// [`Self::with_runtime_buckets`] with an explicit micro-kernel table.
    pub fn with_runtime_mk(
        plan: ExecutionPlan,
        rt: Arc<Runtime>,
        buckets: usize,
        mk: &'static Microkernels,
    ) -> Self {
        // Rebalance the compile-time schedules to the requested bucket
        // count (e.g. a `.grimc` artifact compiled on another host, or
        // a fair-share quota below the pool width). Pure re-scheduling
        // over an immutable plan — never re-packing, never a value
        // buffer copy — and bit-identical for any bucket count.
        let (sched, _) = rebalance_partitions(&plan.steps, &plan.schedules, buckets);
        let workspaces = Arc::new(WorkspacePool::new(plan.memory.arena_len));
        trace::init_from_env();
        let step_bytes = plan.steps.iter().map(|(_, s)| step_weight_bytes(s)).collect();
        Engine {
            plan,
            rt,
            sched: RwLock::new(Arc::new(sched)),
            workspaces,
            mk,
            step_bytes,
            trace_model: AtomicU32::new(0),
            collect_metrics: false,
        }
    }

    /// Interned trace id of the model name, resolved lazily on the first
    /// sampled run (never called on the tracing-off path).
    fn resolve_trace_model(&self) -> u32 {
        let cached = self.trace_model.load(Ordering::Relaxed);
        if cached != 0 {
            return cached;
        }
        let id = trace::intern(&self.plan.name);
        self.trace_model.store(id, Ordering::Relaxed);
        id
    }

    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// The micro-kernel table this engine dispatches to.
    pub fn microkernels(&self) -> &'static Microkernels {
        self.mk
    }

    /// The execution runtime this engine dispatches on.
    pub fn runtime(&self) -> Arc<Runtime> {
        Arc::clone(&self.rt)
    }

    /// Worker count of the (possibly shared) runtime pool.
    pub fn threads(&self) -> usize {
        self.rt.threads()
    }

    #[inline]
    fn pool(&self) -> &ThreadPool {
        self.rt.pool()
    }

    /// Snapshot of the engine's active parallel schedules.
    pub fn schedules(&self) -> Arc<ScheduleSet> {
        Arc::clone(&self.sched.read().unwrap())
    }

    /// Rebalance the engine's schedules to `buckets` worker buckets
    /// (quota changes). Pure metadata: rebuilds `WorkPartition`s from the
    /// immutable plan and atomically installs the new set — in-flight
    /// inferences finish on the old snapshot. Returns the number of
    /// kernel schedules rebuilt.
    pub fn rebalance(&self, buckets: usize) -> usize {
        let current = self.schedules();
        let (next, rebuilt) = rebalance_partitions(&self.plan.steps, &current, buckets);
        *self.sched.write().unwrap() = Arc::new(next);
        rebuilt
    }

    /// Handle to the engine's arena pool (serving stats, zero-alloc tests).
    pub fn workspace_pool(&self) -> Arc<WorkspacePool> {
        Arc::clone(&self.workspaces)
    }

    /// Run one inference; returns the output tensor.
    pub fn run(&self, input: &Tensor) -> anyhow::Result<Tensor> {
        Ok(self.run_with_metrics(input)?.0)
    }

    /// Run one inference, returning output + per-layer metrics. Checks a
    /// workspace out of the pool and executes the planned path.
    pub fn run_with_metrics(&self, input: &Tensor) -> anyhow::Result<(Tensor, RunMetrics)> {
        let mut ws = self.workspaces.checkout();
        self.run_planned(input, &mut ws)
    }

    /// Planned execution in a caller-provided workspace (the arena must
    /// match this plan's `memory.arena_len`).
    pub fn run_planned(
        &self,
        input: &Tensor,
        ws: &mut Workspace,
    ) -> anyhow::Result<(Tensor, RunMetrics)> {
        let mem = &self.plan.memory;
        anyhow::ensure!(
            ws.arena_len() == mem.arena_len,
            "workspace arena {} != plan arena {}",
            ws.arena_len(),
            mem.arena_len
        );
        // Full-dims check, not just numel: a transposed same-numel input
        // would otherwise be silently reinterpreted via the planned shapes.
        let expect = &mem.shapes[self.plan.input_id];
        anyhow::ensure!(
            input.shape().dims() == expect.as_slice(),
            "input shape {:?} does not match model input {:?}",
            input.shape().dims(),
            expect
        );
        let mut metrics = RunMetrics::default();
        if self.collect_metrics {
            metrics.layers.reserve(self.plan.steps.len());
            // Sticky-on busy-time accounting (one relaxed load when
            // already on) so parallel steps get a wall-vs-busy split.
            if !crate::obs::pool_timing() {
                crate::obs::set_pool_timing(true);
            }
        }
        // One schedule snapshot per inference: a concurrent rebalance
        // (quota change) swaps the Arc; this run keeps its consistent set.
        let sched = self.schedules();
        // Tracing-off cost of this whole block: the one relaxed load
        // inside `begin` (it returns None without reading the clock).
        let run_start = trace::begin();
        let tmodel = match run_start {
            Some(_) => {
                let id = self.resolve_trace_model();
                trace::set_current_model(id); // labels worker-lane spans
                id
            }
            None => 0,
        };
        for (i, (id, step)) in self.plan.steps.iter().enumerate() {
            let t = Timer::start();
            // Task-scoped (thread-local) busy deltas: pool barriers credit
            // each call's worker time to the calling thread, so this step's
            // delta is exact even when other dispatcher lanes run
            // concurrently on the shared pool.
            let busy0 = if self.collect_metrics { crate::obs::task_busy_nanos() } else { 0 };
            let kind = self.exec_step_planned(*id, step, input, ws, &sched)?;
            if self.collect_metrics {
                let busy = crate::obs::task_busy_nanos() - busy0;
                metrics.layers.push(LayerMetric {
                    node: *id,
                    kind,
                    micros: t.elapsed_us(),
                    busy_micros: busy as f64 / 1e3,
                    weight_bytes: self.step_bytes[i],
                });
            }
            if run_start.is_some() {
                trace::record_span(
                    trace::SpanKind::Step,
                    t.started_at(),
                    Instant::now(),
                    trace::step_kind_id(kind),
                    tmodel,
                    *id as u64,
                );
            }
        }
        if let Some(start) = run_start {
            trace::record_span(trace::SpanKind::Run, start, Instant::now(), 0, tmodel, 0);
        }
        let out = match mem.value_range(self.plan.output_id) {
            Some((off, len)) => {
                Tensor::from_vec(&mem.shapes[self.plan.output_id], ws.slice(off, len).to_vec())
            }
            // Degenerate plan whose output is the external input.
            None => input.clone(),
        };
        Ok((out, metrics))
    }

    // ---------------------------------------------------------------
    // Planned path
    // ---------------------------------------------------------------

    /// Arena range of `id`'s input in `slot`; `None` means the external
    /// input tensor.
    fn src_range(&self, id: usize, slot: usize) -> anyhow::Result<Option<(usize, usize)>> {
        let src = self.plan.inputs[id]
            .get(slot)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("node {id}: missing input {slot}"))?;
        if let Some(r) = self.plan.memory.value_range(src) {
            return Ok(Some(r));
        }
        anyhow::ensure!(
            src == self.plan.input_id,
            "node {id}: input {src} has no planned buffer"
        );
        Ok(None)
    }

    /// Output dims of `id`'s input in `slot` (for dims-carrying kernels).
    fn src_dims(&self, id: usize, slot: usize) -> &[usize] {
        &self.plan.memory.shapes[self.plan.inputs[id][slot]]
    }

    /// Arena range of `id`'s own value buffer.
    fn out_range(&self, id: usize) -> anyhow::Result<(usize, usize)> {
        self.plan
            .memory
            .value_range(id)
            .ok_or_else(|| anyhow::anyhow!("node {id}: no planned output buffer"))
    }

    /// Borrow (output, input) where the input is either an arena value or
    /// the external input tensor.
    fn out_and_in<'w>(
        &self,
        ws: &'w mut Workspace,
        out_r: (usize, usize),
        src: Option<(usize, usize)>,
        input: &'w Tensor,
    ) -> (&'w mut [f32], &'w [f32]) {
        match src {
            Some(in_r) => {
                let (o, i) = ws.split2_mut(out_r, in_r);
                (o, &*i)
            }
            None => (ws.slice_mut(out_r.0, out_r.1), input.data()),
        }
    }

    /// Borrow (output, gather scratch, input) for a GEMV-style step.
    fn gemm_operands<'w>(
        &self,
        ws: &'w mut Workspace,
        out_r: (usize, usize),
        gather_r: Option<(usize, usize)>,
        src: Option<(usize, usize)>,
        input: &'w Tensor,
    ) -> (&'w mut [f32], &'w mut [f32], &'w [f32]) {
        match (src, gather_r) {
            (Some(in_r), Some(g_r)) => {
                let (out, gather, xin) = ws.split3_mut(out_r, g_r, in_r);
                (out, gather, &*xin)
            }
            (Some(in_r), None) => {
                let (out, xin) = ws.split2_mut(out_r, in_r);
                (out, &mut [], &*xin)
            }
            (None, Some(g_r)) => {
                let (out, gather) = ws.split2_mut(out_r, g_r);
                (out, gather, input.data())
            }
            (None, None) => (ws.slice_mut(out_r.0, out_r.1), &mut [], input.data()),
        }
    }

    fn exec_step_planned(
        &self,
        id: usize,
        step: &Step,
        input: &Tensor,
        ws: &mut Workspace,
        sched: &ScheduleSet,
    ) -> anyhow::Result<&'static str> {
        let mem = &self.plan.memory;
        let kind = match step {
            Step::Input => "input", // read in place from the caller's tensor
            Step::Noop => "noop",   // fused away at compile time
            Step::Conv { geom, kernel, dead_cols, bias, act } => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                if let KernelImpl::Winograd { ut, .. } = kernel {
                    // OptDense baseline: kernel transforms precomputed at
                    // compile time, per-tile input transforms in a
                    // planned arena slice — no per-call allocation. The
                    // epilogue stays two-pass (baseline parity).
                    let scratch_r = mem
                        .scratch_range(id)
                        .ok_or_else(|| anyhow::anyhow!("node {id}: winograd missing scratch"))?;
                    let (out, vbuf, xin) =
                        self.gemm_operands(ws, out_r, Some(scratch_r), src, input);
                    conv2d_winograd_into(
                        xin, geom.in_c, geom.in_h, geom.in_w, ut, geom.out_c, geom.pad, out,
                        vbuf,
                    );
                    ops::add_bias_slice(out, bias);
                    apply_act_slice(out, *act);
                } else {
                    let ep = epilogue_of(bias, *act);
                    let n = geom.gemm_n();
                    let sc = ConvScratch::for_step(geom, kernel);
                    if sc.im2col == 0 {
                        // 1×1/s1/p0: im2col is the identity; GEMM straight
                        // off the input viewed as [C, H*W].
                        let gather_r = mem.scratch_range(id);
                        let (out, gather, xin) =
                            self.gemm_operands(ws, out_r, gather_r, src, input);
                        self.exec_gemm_into(kernel, sched, xin, n, out, gather, ep)?;
                    } else {
                        let scratch_r = mem
                            .scratch_range(id)
                            .ok_or_else(|| anyhow::anyhow!("node {id}: conv missing scratch"))?;
                        {
                            let (scratch, xin) = self.out_and_in(ws, scratch_r, src, input);
                            im2col_into(
                                xin,
                                geom,
                                dead_cols.as_deref().map(|d| d.as_slice()),
                                &mut scratch[..sc.im2col],
                            );
                        }
                        let (out, scratch) = ws.split2_mut(out_r, scratch_r);
                        let (cols, gather) = scratch.split_at_mut(sc.im2col);
                        self.exec_gemm_into(kernel, sched, cols, n, out, gather, ep)?;
                    }
                }
                "conv"
            }
            Step::DwConv { stride, pad, w, bias, act, .. } => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let d = self.src_dims(id, 0);
                let (c, h, wd) = (d[0], d[1], d[2]);
                let (out, xin) = self.out_and_in(ws, out_r, src, input);
                depthwise_conv2d_into_ep(
                    xin,
                    c,
                    h,
                    wd,
                    w,
                    *stride,
                    *pad,
                    out,
                    Some(self.pool()),
                    self.mk,
                    epilogue_of(bias, *act),
                );
                "dwconv"
            }
            Step::Fc { kernel, bias, act } => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let gather_r = mem.scratch_range(id);
                let (out, gather, xin) = self.gemm_operands(ws, out_r, gather_r, src, input);
                self.exec_gemm_into(kernel, sched, xin, 1, out, gather, epilogue_of(bias, *act))?;
                "fc"
            }
            Step::Gru { layers } => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let sdims = self.src_dims(id, 0);
                let (t_len, in_f0) = (sdims[0], sdims[1]);
                let scratch_r = mem
                    .scratch_range(id)
                    .ok_or_else(|| anyhow::anyhow!("node {id}: gru missing scratch"))?;
                let gl = GruScratch::for_layers(layers, t_len);
                let (final_off, h_last) = {
                    let (scratch, xin) = self.out_and_in(ws, scratch_r, src, input);
                    self.exec_gru_scratch(layers, sched, t_len, in_f0, xin, scratch, gl)?
                };
                let (out, scratch) = ws.split2_mut(out_r, scratch_r);
                out.copy_from_slice(&scratch[final_off..final_off + t_len * h_last]);
                "gru"
            }
            Step::MaxPool2 => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let d = self.src_dims(id, 0);
                let (c, h, w) = (d[0], d[1], d[2]);
                let (out, xin) = self.out_and_in(ws, out_r, src, input);
                ops::maxpool2_into(xin, c, h, w, out);
                "maxpool"
            }
            Step::GlobalAvgPool => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let d = self.src_dims(id, 0);
                let (c, h, w) = (d[0], d[1], d[2]);
                let (out, xin) = self.out_and_in(ws, out_r, src, input);
                ops::global_avgpool_into(xin, c, h, w, out);
                "gap"
            }
            Step::Relu => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                // In-place elision: when the planner proved this step is
                // its producer's final reader it aliased the buffers, so
                // the activation runs directly over the producer's bytes.
                if src == Some(out_r) {
                    ops::relu_slice(ws.slice_mut(out_r.0, out_r.1));
                } else {
                    let (out, xin) = self.out_and_in(ws, out_r, src, input);
                    out.copy_from_slice(xin);
                    ops::relu_slice(out);
                }
                "relu"
            }
            Step::Relu6 => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                if src == Some(out_r) {
                    ops::relu6_slice(ws.slice_mut(out_r.0, out_r.1));
                } else {
                    let (out, xin) = self.out_and_in(ws, out_r, src, input);
                    out.copy_from_slice(xin);
                    ops::relu6_slice(out);
                }
                "relu6"
            }
            Step::Add { act } => {
                let out_r = self.out_range(id)?;
                let src0 = self.src_range(id, 0)?;
                let src1 = self.src_range(id, 1)?;
                {
                    let (out, a) = self.out_and_in(ws, out_r, src0, input);
                    out.copy_from_slice(a);
                }
                let (out, b) = self.out_and_in(ws, out_r, src1, input);
                ops::add_act_slice(out, b, act.to_act());
                "add"
            }
            Step::Flatten => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                // In-place elision: the planner aliases a single-consumer
                // Flatten onto its producer's buffer — nothing to do.
                if src != Some(out_r) {
                    let (out, xin) = self.out_and_in(ws, out_r, src, input);
                    out.copy_from_slice(xin);
                }
                "flatten"
            }
            Step::Softmax => {
                let out_r = self.out_range(id)?;
                let src = self.src_range(id, 0)?;
                let (out, xin) = self.out_and_in(ws, out_r, src, input);
                ops::softmax_rows_into(xin, xin.len(), out);
                "softmax"
            }
        };
        Ok(kind)
    }

    // ---------------------------------------------------------------
    // Naive reference path
    // ---------------------------------------------------------------

    /// Reference interpreter holding every intermediate as an owned
    /// tensor. The planned path is property-tested to match it
    /// bit-for-bit; it shares all kernel dispatch below.
    pub fn run_naive(&self, input: &Tensor) -> anyhow::Result<Tensor> {
        let n = self.plan.steps.len();
        let mut values: Vec<Option<Tensor>> = vec![None; n];
        let sched = self.schedules();
        for (id, step) in &self.plan.steps {
            let out = self.exec_step_naive(*id, step, input, &values, &sched)?;
            values[*id] = out;
        }
        match values[self.plan.output_id].take() {
            Some(out) => Ok(out),
            None => {
                anyhow::ensure!(
                    self.plan.output_id == self.plan.input_id,
                    "output not produced"
                );
                Ok(input.clone())
            }
        }
    }

    fn value<'a>(
        &self,
        values: &'a [Option<Tensor>],
        input: &'a Tensor,
        id: usize,
        slot: usize,
    ) -> anyhow::Result<&'a Tensor> {
        let src = self.plan.inputs[id]
            .get(slot)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("node {id}: missing input {slot}"))?;
        if let Some(v) = values[src].as_ref() {
            return Ok(v);
        }
        // The external input is read in place (no passthrough clone).
        anyhow::ensure!(src == self.plan.input_id, "node {id}: input {src} not computed");
        Ok(input)
    }

    fn exec_step_naive(
        &self,
        id: usize,
        step: &Step,
        input: &Tensor,
        values: &[Option<Tensor>],
        sched: &ScheduleSet,
    ) -> anyhow::Result<Option<Tensor>> {
        Ok(match step {
            Step::Input => None, // consumers read the caller's tensor
            Step::Noop => None,  // fused away; consumers were redirected
            Step::Conv { geom, kernel, dead_cols, bias, act } => {
                let x = self.value(values, input, id, 0)?;
                if let KernelImpl::Winograd { w4, .. } = kernel {
                    // Winograd stays unfused (baseline-only path).
                    let mut out = conv2d_winograd(x, w4, geom.pad);
                    ops::add_bias_(&mut out, bias);
                    apply_act(&mut out, *act);
                    Some(out)
                } else {
                    let ep = epilogue_of(bias, *act);
                    let out =
                        self.exec_conv_gemm(geom, kernel, sched, dead_cols.as_deref(), x, ep)?;
                    Some(out.reshape(&[geom.out_c, geom.out_h(), geom.out_w()]))
                }
            }
            Step::DwConv { stride, pad, w, bias, act, .. } => {
                let x = self.value(values, input, id, 0)?;
                Some(depthwise_conv2d_parallel_ep(
                    x,
                    w,
                    *stride,
                    *pad,
                    self.pool(),
                    self.mk,
                    epilogue_of(bias, *act),
                ))
            }
            Step::Fc { kernel, bias, act } => {
                let x = self.value(values, input, id, 0)?;
                let out =
                    self.exec_gemm_alloc(kernel, sched, x.data(), 1, epilogue_of(bias, *act))?;
                let rows = out.shape().dim(0);
                Some(out.reshape(&[rows]))
            }
            Step::Gru { layers } => {
                let x = self.value(values, input, id, 0)?;
                Some(self.exec_gru(layers, sched, x)?)
            }
            Step::MaxPool2 => Some(ops::maxpool2(self.value(values, input, id, 0)?)),
            Step::GlobalAvgPool => Some(ops::global_avgpool(self.value(values, input, id, 0)?)),
            Step::Relu => {
                let mut v = self.value(values, input, id, 0)?.clone();
                ops::relu_(&mut v);
                Some(v)
            }
            Step::Relu6 => {
                let mut v = self.value(values, input, id, 0)?.clone();
                ops::relu6_(&mut v);
                Some(v)
            }
            Step::Add { act } => {
                let mut a = self.value(values, input, id, 0)?.clone();
                let b = self.value(values, input, id, 1)?;
                assert_eq!(a.shape(), b.shape());
                ops::add_act_slice(a.data_mut(), b.data(), act.to_act());
                Some(a)
            }
            Step::Flatten => {
                let v = self.value(values, input, id, 0)?.clone();
                let n = v.numel();
                Some(v.reshape(&[n]))
            }
            Step::Softmax => {
                let v = self.value(values, input, id, 0)?;
                let n = v.numel();
                Some(ops::softmax_rows(v, n).reshape(&[n]))
            }
        })
    }

    /// Naive-path conv as im2col + GEMM with fused epilogue (Winograd is
    /// handled by the caller — it never runs as a plain GEMM).
    #[allow(clippy::too_many_arguments)]
    fn exec_conv_gemm(
        &self,
        geom: &ConvGeom,
        kernel: &KernelImpl,
        sched: &ScheduleSet,
        dead: Option<&Vec<bool>>,
        x: &Tensor,
        ep: Epilogue<'_>,
    ) -> anyhow::Result<Tensor> {
        // 1x1 stride-1 convs: im2col is the identity — feed x directly
        // ([C,H,W] viewed as [C, H*W]); MobileNet is mostly this case.
        if layout::conv_is_identity_im2col(geom) {
            return self.exec_gemm_alloc(kernel, sched, x.data(), geom.in_h * geom.in_w, ep);
        }
        let cols = match dead {
            Some(d) => im2col_skip(x, geom, d),
            None => im2col(x, geom),
        };
        self.exec_gemm_alloc(kernel, sched, cols.data(), geom.gemm_n(), ep)
    }

    // ---------------------------------------------------------------
    // Shared kernel dispatch
    // ---------------------------------------------------------------

    /// Allocating GEMM used by the naive path; routes through
    /// [`Self::exec_gemm_into`] so both paths run identical kernels.
    fn exec_gemm_alloc(
        &self,
        kernel: &KernelImpl,
        sched: &ScheduleSet,
        xd: &[f32],
        n: usize,
        ep: Epilogue<'_>,
    ) -> anyhow::Result<Tensor> {
        let m = kernel
            .out_rows()
            .ok_or_else(|| anyhow::anyhow!("winograd outside conv"))?;
        let mut out = Tensor::zeros(&[m, n]);
        // Same carve the planner reserves: [gemv gather][quant scratch].
        let mut gather = vec![
            0.0f32;
            (if n == 1 { layout::kernel_gather_len(kernel) } else { 0 })
                + layout::kernel_quant_len(kernel, n)
        ];
        self.exec_gemm_into(kernel, sched, xd, n, out.data_mut(), &mut gather, ep)?;
        Ok(out)
    }

    /// The single kernel-dispatch point: `out[M,N] = W · X[K,N]` with `x`
    /// and `out` as flat slices; `gather` is gemv scratch for BCRC, `ep`
    /// the fused bias/activation epilogue. Every kernel runs on the
    /// engine's dispatched [`Microkernels`]; parallel kernels resolve
    /// their static partition through `sched` (the engine's active,
    /// quota-rebalanced `ScheduleSet` snapshot).
    #[allow(clippy::too_many_arguments)]
    fn exec_gemm_into(
        &self,
        kernel: &KernelImpl,
        sched: &ScheduleSet,
        xd: &[f32],
        n: usize,
        out: &mut [f32],
        gather: &mut [f32],
        ep: Epilogue<'_>,
    ) -> anyhow::Result<()> {
        match kernel {
            KernelImpl::NaiveDense { w } => naive_gemm_dense_into_ep(w, xd, n, out, self.mk, ep),
            KernelImpl::Dense { w, params, packed, sched: sid } => {
                let (m, _) = w.shape().as_matrix();
                let parallel = m * n >= PARALLEL_THRESHOLD;
                match (packed, parallel) {
                    (Some(pd), true) => tiled_gemm_packed_parallel_into_ep(
                        pd, xd, n, *params, sched.get(*sid), self.pool(), out, self.mk, ep,
                    ),
                    (Some(pd), false) => {
                        tiled_gemm_packed_into_ep(pd, xd, n, *params, out, self.mk, ep)
                    }
                    (None, true) => tiled_gemm_parallel_into_ep(
                        w, xd, n, *params, self.pool(), out, self.mk, ep,
                    ),
                    (None, false) => tiled_gemm_into_ep(w, xd, n, *params, out, self.mk, ep),
                }
            }
            KernelImpl::Winograd { .. } => anyhow::bail!("winograd outside conv"),
            KernelImpl::Csr { mat, sched: sid } => {
                if mat.rows * n >= PARALLEL_THRESHOLD {
                    match sched.get(*sid) {
                        // Compile-time nnz-balanced row partition beats
                        // the even row split on skewed sparsity.
                        Some(wp) => csr_gemm_partitioned_into_ep(
                            mat, wp, xd, n, self.pool(), out, self.mk, ep,
                        ),
                        None => {
                            csr_gemm_parallel_into_ep(mat, xd, n, self.pool(), out, self.mk, ep)
                        }
                    }
                } else {
                    csr_gemm_into_ep(mat, xd, n, out, self.mk, ep);
                }
            }
            KernelImpl::Bcrc { gemm } => {
                // Quantization scratch rides at the tail of the planned
                // gather region (see memory::layout); zero-length for
                // every f32 kernel.
                let ql = layout::kernel_quant_len(kernel, n);
                let (gather, quant) = gather.split_at_mut(gather.len() - ql);
                // The i8 layout serves every shape it was packed for; the
                // one mismatch (gemv over an interleaved packing) routes
                // through the encode-order f32 path below, which reads
                // the original values retained in `gemm.enc`.
                let i8_ok = gemm
                    .packed
                    .as_deref()
                    .is_some_and(|p| p.dtype == crate::quant::DType::I8 && (n > 1 || p.row_major));
                if i8_ok {
                    let p = gemm.packed.as_deref().expect("checked above");
                    // Dynamic per-tensor activation quantization: range,
                    // params, then u8 codes staged in the quant scratch.
                    let (lo, hi) = crate::quant::minmax(xd);
                    let qx = crate::quant::choose_qparams(lo, hi);
                    let codes = gemm.enc.cols * n;
                    let cslots = crate::quant::f32_slots_for_bytes(codes);
                    let (cbuf, gbuf) = quant.split_at_mut(cslots);
                    let xq = crate::quant::as_u8_mut(cbuf);
                    crate::quant::quantize_activations(xd, qx, &mut xq[..codes]);
                    let part = sched.get(gemm.sched);
                    if gemm.enc.rows * n >= PARALLEL_THRESHOLD && part.is_some() {
                        gemm.execute_i8_parallel_into_ep(
                            &xq[..codes],
                            n,
                            out,
                            part.expect("checked above"),
                            self.pool(),
                            qx,
                            self.mk,
                            ep,
                        );
                    } else {
                        let g8 = crate::quant::as_u8_mut(gbuf);
                        let gw = if n == 1 { p.max_width } else { 0 };
                        gemm.execute_i8_into_ep(
                            &xq[..codes],
                            n,
                            out,
                            &mut g8[..gw],
                            qx,
                            self.mk,
                            ep,
                        );
                    }
                } else if gemm.enc.rows * n >= PARALLEL_THRESHOLD {
                    gemm.execute_parallel_into_ep(
                        xd,
                        n,
                        out,
                        sched.get(gemm.sched),
                        self.pool(),
                        self.mk,
                        ep,
                    );
                } else {
                    gemm.execute_into_ep(xd, n, out, gather, self.mk, ep);
                }
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // GRU (shared core)
    // ---------------------------------------------------------------

    /// Naive-path GRU: allocates one scratch region and defers to the
    /// shared layer core.
    fn exec_gru(
        &self,
        layers: &[GruLayerPlan],
        sched: &ScheduleSet,
        x: &Tensor,
    ) -> anyhow::Result<Tensor> {
        let (t_len, in_f0) = x.shape().as_matrix();
        let gl = GruScratch::for_layers(layers, t_len);
        let mut scratch = vec![0.0f32; gl.total()];
        let (off, h_last) =
            self.exec_gru_scratch(layers, sched, t_len, in_f0, x.data(), &mut scratch, gl)?;
        Ok(Tensor::from_vec(&[t_len, h_last], scratch[off..off + t_len * h_last].to_vec()))
    }

    /// Run the whole GRU stack inside `scratch` (laid out per
    /// [`GruScratch`]); returns `(offset, hidden)` of the final `[T, H]`
    /// sequence within `scratch`.
    #[allow(clippy::too_many_arguments)]
    fn exec_gru_scratch(
        &self,
        layers: &[GruLayerPlan],
        sched: &ScheduleSet,
        t_len: usize,
        in_f0: usize,
        xin: &[f32],
        scratch: &mut [f32],
        gl: GruScratch,
    ) -> anyhow::Result<(usize, usize)> {
        anyhow::ensure!(!layers.is_empty(), "empty GRU stack");
        anyhow::ensure!(xin.len() == t_len * in_f0, "gru input length mismatch");
        anyhow::ensure!(scratch.len() >= gl.total(), "gru scratch too small");
        let (seq_a, rest) = scratch.split_at_mut(gl.seq);
        let (seq_b, rest) = rest.split_at_mut(gl.seq);
        let (cat, rest) = rest.split_at_mut(gl.cat);
        let (cat2, rest) = rest.split_at_mut(gl.cat);
        let (z, rest) = rest.split_at_mut(gl.h);
        let (r, rest) = rest.split_at_mut(gl.h);
        let (hc, rest) = rest.split_at_mut(gl.h);
        let (hidden, rest) = rest.split_at_mut(gl.h);
        let gather = &mut rest[..gl.gather];

        let mut in_f = in_f0;
        for (l, layer) in layers.iter().enumerate() {
            anyhow::ensure!(in_f == layer.in_f, "gru input width mismatch");
            let h = layer.hidden;
            hidden[..h].fill(0.0);
            let (src_seq, dst_seq): (&[f32], &mut [f32]) = if l == 0 {
                (xin, &mut *seq_a)
            } else if l % 2 == 1 {
                (&*seq_a, &mut *seq_b)
            } else {
                (&*seq_b, &mut *seq_a)
            };
            self.gru_layer(
                layer, sched, t_len, src_seq, dst_seq, cat, cat2, z, r, hc, hidden, gather,
            )?;
            in_f = h;
        }
        let h_last = layers[layers.len() - 1].hidden;
        let final_off = if (layers.len() - 1) % 2 == 0 { 0 } else { gl.seq };
        Ok((final_off, h_last))
    }

    /// One GRU layer over a `[T, in_f]` sequence — the single
    /// implementation both execution paths use.
    #[allow(clippy::too_many_arguments)]
    fn gru_layer(
        &self,
        layer: &GruLayerPlan,
        sched: &ScheduleSet,
        t_len: usize,
        src: &[f32],
        dst: &mut [f32],
        cat: &mut [f32],
        cat2: &mut [f32],
        z: &mut [f32],
        r: &mut [f32],
        hc: &mut [f32],
        hidden: &mut [f32],
        gather: &mut [f32],
    ) -> anyhow::Result<()> {
        let in_f = layer.in_f;
        let h = layer.hidden;
        let cat_w = in_f + h;
        for t in 0..t_len {
            let xt = &src[t * in_f..(t + 1) * in_f];
            cat[..in_f].copy_from_slice(xt);
            cat[in_f..cat_w].copy_from_slice(&hidden[..h]);
            self.gate_into(&layer.wz, sched, &cat[..cat_w], &layer.bz, true, &mut z[..h], gather)?;
            self.gate_into(&layer.wr, sched, &cat[..cat_w], &layer.br, true, &mut r[..h], gather)?;
            // candidate uses [x, r ⊙ h]
            cat2[..in_f].copy_from_slice(&cat[..in_f]);
            for i in 0..h {
                cat2[in_f + i] = r[i] * hidden[i];
            }
            self.gate_into(&layer.wh, sched, &cat2[..cat_w], &layer.bh, false, &mut hc[..h], gather)?;
            for i in 0..h {
                hidden[i] = (1.0 - z[i]) * hidden[i] + z[i] * hc[i];
            }
            dst[t * h..(t + 1) * h].copy_from_slice(&hidden[..h]);
        }
        Ok(())
    }

    /// One gate: GEMV + bias + sigmoid/tanh into `out`.
    #[allow(clippy::too_many_arguments)]
    fn gate_into(
        &self,
        kernel: &KernelImpl,
        sched: &ScheduleSet,
        x: &[f32],
        bias: &[f32],
        sigmoid: bool,
        out: &mut [f32],
        gather: &mut [f32],
    ) -> anyhow::Result<()> {
        self.exec_gemm_into(kernel, sched, x, 1, out, gather, Epilogue::None)?;
        for (o, b) in out.iter_mut().zip(bias) {
            *o += b;
            *o = if sigmoid { 1.0 / (1.0 + (-*o).exp()) } else { o.tanh() };
        }
        Ok(())
    }
}

/// Epilogue for a step's (bias, activation) pair.
fn epilogue_of(bias: &[f32], act: Activation) -> Epilogue<'_> {
    let b = if bias.is_empty() { None } else { Some(bias) };
    Epilogue::from_parts(b, act.to_act())
}

fn apply_act(x: &mut Tensor, act: Activation) {
    apply_act_slice(x.data_mut(), act);
}

fn apply_act_slice(x: &mut [f32], act: Activation) {
    match act {
        Activation::None => {}
        Activation::Relu => ops::relu_slice(x),
        Activation::Relu6 => ops::relu6_slice(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::passes::{compile, Backend, CompileOptions};
    use crate::compiler::weights::{gru_key, LayerWeights, WeightStore};
    use crate::graph::dsl;
    use crate::sparse::{BcrConfig, BcrMask};
    use crate::util::Rng;
    use std::collections::HashMap;

    fn cnn_module() -> dsl::Module {
        dsl::parse(
            r#"
model "tiny"
in = Input(shape=[3,8,8])
c1 = Conv2D(in, out_c=8, kh=3, kw=3, stride=1, pad=1)
r1 = ReLU(c1)
p1 = MaxPool2(r1)
f = Flatten(p1)
fc1 = FC(f, out_f=10)
out = Softmax(fc1)
@ir c1 { block_size=[2,9]; rate=3.0 }
@ir fc1 { block_size=[2,16]; rate=2.0 }
"#,
        )
        .unwrap()
    }

    fn cnn_weights(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut s = HashMap::new();
        let m1 = BcrMask::random(8, 27, BcrConfig::from_block_size(8, 27, 2, 9), 3.0, &mut rng);
        let mut w1 = Tensor::rand_uniform(&[8, 27], 0.5, &mut rng);
        m1.apply(&mut w1);
        s.insert("c1".into(), LayerWeights::dense(w1).with_mask(m1).with_bias(vec![0.1; 8]));
        let m2 = BcrMask::random(10, 128, BcrConfig::from_block_size(10, 128, 2, 16), 2.0, &mut rng);
        let mut w2 = Tensor::rand_uniform(&[10, 128], 0.5, &mut rng);
        m2.apply(&mut w2);
        s.insert("fc1".into(), LayerWeights::dense(w2).with_mask(m2));
        s
    }

    /// All four backends must produce identical outputs on the same
    /// (masked) weights — the cross-backend correctness property that
    /// anchors every speedup claim in the benches.
    #[test]
    fn backends_agree() {
        let m = cnn_module();
        let w = cnn_weights(1);
        let mut rng = Rng::new(42);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let mut outputs = Vec::new();
        for b in [Backend::Grim, Backend::NaiveDense, Backend::OptDense, Backend::CsrSparse] {
            let plan = compile(&m, &w, CompileOptions::for_backend(b)).unwrap();
            let engine = Engine::new(plan, 2);
            outputs.push((b, engine.run(&x).unwrap()));
        }
        let (b0, ref0) = &outputs[0];
        for (b, o) in &outputs[1..] {
            assert!(
                o.allclose(ref0, 1e-3, 1e-3),
                "{b:?} disagrees with {b0:?}: maxdiff={}",
                o.max_abs_diff(ref0)
            );
        }
    }

    #[test]
    fn softmax_output_sums_to_one() {
        let m = cnn_module();
        let w = cnn_weights(2);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let engine = Engine::new(plan, 1);
        let mut rng = Rng::new(7);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let out = engine.run(&x).unwrap();
        assert_eq!(out.numel(), 10);
        let sum: f32 = out.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4);
    }

    #[test]
    fn metrics_collected() {
        let m = cnn_module();
        let w = cnn_weights(3);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let mut engine = Engine::new(plan, 1);
        engine.collect_metrics = true;
        let mut rng = Rng::new(8);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let (_, metrics) = engine.run_with_metrics(&x).unwrap();
        assert_eq!(metrics.layers.len(), 7);
        assert!(metrics.total_micros() > 0.0);
    }

    #[test]
    fn planned_matches_naive_on_cnn() {
        let m = cnn_module();
        let w = cnn_weights(4);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let engine = Engine::new(plan, 2);
        let mut rng = Rng::new(12);
        for _ in 0..3 {
            let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
            let planned = engine.run(&x).unwrap();
            let naive = engine.run_naive(&x).unwrap();
            assert_eq!(planned, naive, "planned path must be bit-identical to naive");
        }
    }

    #[test]
    fn one_checkout_per_run_and_arena_reused() {
        let m = cnn_module();
        let w = cnn_weights(5);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let engine = Engine::new(plan, 1);
        let pool = engine.workspace_pool();
        let mut rng = Rng::new(13);
        for _ in 0..5 {
            let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
            engine.run(&x).unwrap();
        }
        let stats = pool.stats();
        assert_eq!(stats.checkouts, 5, "exactly one arena checkout per inference");
        assert_eq!(stats.arenas_created, 1, "sequential runs must reuse one arena");
        assert!(stats.arena_bytes > 0);
    }

    /// The engine rebalances the compile-time schedules (default 8
    /// buckets) to its actual pool size — pure metadata, zero packed
    /// value-buffer copies even for a *shared* plan — and stays
    /// bit-identical.
    #[test]
    fn engine_rebalances_partitions_to_pool_size() {
        let m = cnn_module();
        let w = cnn_weights(7);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        // Packed-buffer pointers before engine construction: the clone
        // shares the kernel Arcs, which used to force a deep copy.
        let packed_ptrs = |p: &crate::compiler::ExecutionPlan| -> Vec<*const f32> {
            let mut v = Vec::new();
            crate::compiler::plan::for_each_kernel(&p.steps, |k| {
                if let KernelImpl::Bcrc { gemm } = k {
                    if let Some(pk) = &gemm.packed {
                        v.push(pk.values.as_slice().as_ptr());
                    }
                }
            });
            v
        };
        let before = packed_ptrs(&plan);
        let engine = Engine::new(plan.clone(), 3);
        assert_eq!(
            packed_ptrs(engine.plan()),
            before,
            "rebalance must never copy a packed value buffer, even on a shared plan"
        );
        let sched = engine.schedules();
        assert_eq!(sched.threads, 3);
        let mut bcrc = 0;
        for (_, step) in &engine.plan().steps {
            let kernel = match step {
                Step::Conv { kernel, .. } | Step::Fc { kernel, .. } => kernel,
                _ => continue,
            };
            if let KernelImpl::Bcrc { gemm } = kernel {
                if let Some(p) = &gemm.packed {
                    bcrc += 1;
                    let part = sched.get(gemm.sched).expect("packed kernel has a schedule");
                    assert_eq!(part.num_buckets(), 3, "partition must match pool size");
                    part.validate_covers(&p.groups).unwrap();
                }
            }
        }
        if !crate::compiler::packing::force_unpacked() {
            assert!(bcrc > 0, "fixture must exercise packed BCRC layers");
        }
        // Rebalanced engine agrees with an engine at the compile-time width.
        let eight = Engine::new(plan, 8);
        let mut rng = Rng::new(71);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        assert_eq!(engine.run(&x).unwrap(), eight.run(&x).unwrap());
    }

    /// `--dtype i8` serving: the quantized plan tracks the f32 plan
    /// within the quantization error budget, shrinks the packed bytes,
    /// and the planned path still matches the naive reference bitwise
    /// (both route through the same i8 kernels on the same codes).
    #[test]
    fn quantized_plan_tracks_f32_and_matches_naive() {
        let m = cnn_module();
        let w = cnn_weights(9);
        let f32_plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let q_opts = CompileOptions { dtype: crate::quant::DType::I8, ..Default::default() };
        let q_plan = compile(&m, &w, q_opts).unwrap();
        if crate::compiler::packing::force_unpacked() {
            return; // nothing packed to quantize under GRIM_FORCE_UNPACKED
        }
        assert!(q_plan.packing.i8_layers > 0, "fixture must quantize at least one layer");
        assert!(
            q_plan.packing.packed_bytes < f32_plan.packing.packed_bytes,
            "i8 packing must shrink weight bytes: {} vs {}",
            q_plan.packing.packed_bytes,
            f32_plan.packing.packed_bytes
        );
        let ef = Engine::new(f32_plan, 2);
        let eq = Engine::new(q_plan, 2);
        let mut rng = Rng::new(90);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let a = ef.run(&x).unwrap();
        let b = eq.run(&x).unwrap();
        // Post-softmax probabilities; two small quantized layers stay
        // well inside this budget (the tight analytic per-layer bound
        // lives in the bcrc_gemm and tier-2 quant tests).
        assert!(a.allclose(&b, 8e-2, 8e-2), "maxdiff={}", a.max_abs_diff(&b));
        assert_eq!(b, eq.run_naive(&x).unwrap(), "planned i8 must match naive i8 bitwise");
    }

    #[test]
    fn wrong_input_size_rejected() {
        let m = cnn_module();
        let w = cnn_weights(6);
        let plan = compile(&m, &w, CompileOptions::default()).unwrap();
        let engine = Engine::new(plan, 1);
        let bad = Tensor::zeros(&[3, 4, 4]);
        assert!(engine.run(&bad).is_err());
    }

    fn gru_module() -> dsl::Module {
        dsl::parse(
            r#"
model "gru"
x = Input(shape=[5,12])
g = GRU(x, hidden=16, layers=2)
@ir g { block_size=[4,4]; rate=2.0 }
"#,
        )
        .unwrap()
    }

    fn gru_weights(seed: u64, sparse: bool) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut s = HashMap::new();
        let mut in_f = 12usize;
        for l in 0..2 {
            for gate in ['z', 'r', 'h'] {
                let cols = in_f + 16;
                let mut w = Tensor::rand_uniform(&[16, cols], 0.4, &mut rng);
                let lw = if sparse {
                    let mask =
                        BcrMask::random(16, cols, BcrConfig::from_block_size(16, cols, 4, 4), 2.0, &mut rng);
                    mask.apply(&mut w);
                    LayerWeights::dense(w).with_mask(mask)
                } else {
                    LayerWeights::dense(w)
                };
                s.insert(gru_key("g", l, gate), lw);
            }
            in_f = 16;
        }
        s
    }

    #[test]
    fn gru_backends_agree() {
        let m = gru_module();
        let w = gru_weights(5, true);
        let mut rng = Rng::new(9);
        let x = Tensor::rand_uniform(&[5, 12], 1.0, &mut rng);
        let grim = Engine::new(compile(&m, &w, CompileOptions::default()).unwrap(), 1);
        let dense = Engine::new(
            compile(&m, &w, CompileOptions::for_backend(Backend::NaiveDense)).unwrap(),
            1,
        );
        let a = grim.run(&x).unwrap();
        let b = dense.run(&x).unwrap();
        assert_eq!(a.shape().dims(), &[5, 16]);
        assert!(a.allclose(&b, 1e-4, 1e-4), "maxdiff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn gru_hidden_bounded() {
        // dense weights -> module without a BCRC IR pragma
        let m = dsl::parse("model \"gru\"\nx = Input(shape=[5,12])\ng = GRU(x, hidden=16, layers=2)")
            .unwrap();
        let w = gru_weights(6, false);
        let engine = Engine::new(compile(&m, &w, CompileOptions::default()).unwrap(), 1);
        let mut rng = Rng::new(10);
        let x = Tensor::rand_uniform(&[5, 12], 2.0, &mut rng);
        let out = engine.run(&x).unwrap();
        // GRU hidden state is a convex combination of tanh outputs => |h| <= 1
        assert!(out.data().iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn gru_planned_matches_naive() {
        let m = gru_module();
        let w = gru_weights(7, true);
        let engine = Engine::new(compile(&m, &w, CompileOptions::default()).unwrap(), 1);
        let mut rng = Rng::new(11);
        let x = Tensor::rand_uniform(&[5, 12], 1.0, &mut rng);
        let a = engine.run(&x).unwrap();
        let b = engine.run_naive(&x).unwrap();
        assert_eq!(a, b);
    }
}
