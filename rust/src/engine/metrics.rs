//! Per-layer and per-run execution metrics (feeds Figures 13–15 and the
//! coordinator's latency reporting).

/// Timing + instrumentation for one executed step.
#[derive(Clone, Debug)]
pub struct LayerMetric {
    pub node: usize,
    pub kind: &'static str,
    pub micros: f64,
}

/// Metrics for one full inference.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub layers: Vec<LayerMetric>,
}

impl RunMetrics {
    pub fn total_micros(&self) -> f64 {
        self.layers.iter().map(|l| l.micros).sum()
    }

    pub fn total_ms(&self) -> f64 {
        self.total_micros() / 1e3
    }

    /// Time attributed to one node id.
    pub fn node_micros(&self, node: usize) -> f64 {
        self.layers.iter().filter(|l| l.node == node).map(|l| l.micros).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let m = RunMetrics {
            layers: vec![
                LayerMetric { node: 0, kind: "conv", micros: 100.0 },
                LayerMetric { node: 1, kind: "fc", micros: 50.0 },
            ],
        };
        assert_eq!(m.total_micros(), 150.0);
        assert_eq!(m.node_micros(1), 50.0);
        assert!((m.total_ms() - 0.15).abs() < 1e-12);
    }
}
