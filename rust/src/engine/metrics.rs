//! Per-layer and per-run execution metrics (feeds Figures 13–15 and the
//! coordinator's latency reporting).

/// Timing + instrumentation for one executed step.
///
/// `micros` is wall time for the step; `busy_micros` is the *summed*
/// time threadpool workers spent inside the step's chunks, so for a
/// parallel step `busy_micros / micros` approximates effective worker
/// occupancy (≈ the step's parallel speedup), while a serial step has
/// `busy_micros == 0`. The split is what the paper's per-layer figures
/// need: wall time answers "where does latency go", busy time answers
/// "was the pool actually used".
#[derive(Clone, Debug)]
pub struct LayerMetric {
    pub node: usize,
    pub kind: &'static str,
    /// Wall-clock step time.
    pub micros: f64,
    /// Summed per-worker busy time inside the step's own pool chunks.
    /// Task-scoped (`crate::obs::task_busy_nanos`): exact even when
    /// other engines run concurrently on the shared pool — 0 for
    /// serial steps.
    pub busy_micros: f64,
    /// Resident weight bytes the step's kernel reads (packed size when
    /// a packed layout exists, encoded size otherwise; 0 for
    /// weightless steps).
    pub weight_bytes: usize,
}

/// Metrics for one full inference.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub layers: Vec<LayerMetric>,
}

impl RunMetrics {
    pub fn total_micros(&self) -> f64 {
        self.layers.iter().map(|l| l.micros).sum()
    }

    pub fn total_ms(&self) -> f64 {
        self.total_micros() / 1e3
    }

    /// Summed worker busy time across all steps.
    pub fn total_busy_micros(&self) -> f64 {
        self.layers.iter().map(|l| l.busy_micros).sum()
    }

    /// Total weight bytes touched across all steps.
    pub fn total_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bytes).sum()
    }

    /// Time attributed to one node id.
    pub fn node_micros(&self, node: usize) -> f64 {
        self.layers.iter().filter(|l| l.node == node).map(|l| l.micros).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let m = RunMetrics {
            layers: vec![
                LayerMetric {
                    node: 0,
                    kind: "conv",
                    micros: 100.0,
                    busy_micros: 320.0,
                    weight_bytes: 4096,
                },
                LayerMetric {
                    node: 1,
                    kind: "fc",
                    micros: 50.0,
                    busy_micros: 0.0,
                    weight_bytes: 1024,
                },
            ],
        };
        assert_eq!(m.total_micros(), 150.0);
        assert_eq!(m.node_micros(1), 50.0);
        assert!((m.total_ms() - 0.15).abs() < 1e-12);
        assert_eq!(m.total_busy_micros(), 320.0);
        assert_eq!(m.total_weight_bytes(), 5120);
    }
}
