//! The `.grimc` acceptance invariant the whole AOT story rests on: the
//! load path performs **no BCR re-encoding and no re-packing** — the
//! expensive pipeline ran offline, serving only moves bytes. Verified
//! via the thread-local pack-invocation counter
//! (`sparse::packed::pack_invocations`), which every packing transform
//! bumps and which must therefore stay flat across loads and across
//! engine construction (whose per-pool partition rebalance is pure
//! re-scheduling).

use grim::artifact;
use grim::compiler::passes::{compile, CompileOptions};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::sparse::packed::pack_invocations;
use grim::tensor::Tensor;
use grim::util::Rng;

#[test]
fn load_path_never_packs() {
    // Compile (this *does* pack — the offline half of the story).
    let o = InitOptions { rate: 6.0, block: [4, 16], seed: 42 };
    let m = build_model(ModelKind::Vgg16, Preset::CifarMini, o);
    let w = random_weights(&m, o);
    let plan = compile(&m, &w, CompileOptions::default()).unwrap();
    let compile_packs = pack_invocations();
    if !grim::compiler::packing::force_unpacked() {
        assert!(compile_packs > 0, "compilation must have packed layers");
    }
    let bytes = artifact::to_bytes(&plan).unwrap();

    // Serving half: save/load cycles and engine construction (at several
    // pool sizes, exercising the partition rebalance) pack nothing.
    let before = pack_invocations();
    let loaded = artifact::from_bytes(&bytes).unwrap();
    let loaded2 = artifact::from_bytes(&bytes).unwrap();
    assert_eq!(pack_invocations(), before, "artifact loads must not re-pack");
    let e3 = Engine::new(loaded, 3);
    let e8 = Engine::new(loaded2, 8);
    assert_eq!(
        pack_invocations(),
        before,
        "engine construction (partition rebalance) must not re-pack"
    );

    // And the loaded engines still agree with the in-memory plan.
    let mem = Engine::new(plan, 2);
    assert_eq!(pack_invocations(), before, "engine over an in-memory plan must not re-pack");
    let mut rng = Rng::new(0xAA07);
    let dims = mem.plan().memory.shapes[mem.plan().input_id].clone();
    let x = Tensor::rand_uniform(&dims, 1.0, &mut rng);
    let a = mem.run(&x).unwrap();
    assert_eq!(a, e3.run(&x).unwrap());
    assert_eq!(a, e8.run(&x).unwrap());
}
