//! Packed-layout acceptance tests:
//!
//! * packed plans (the default) are **bit-identical** to unpacked plans
//!   (`CompileOptions::without_packing`, the `GRIM_FORCE_UNPACKED=1`
//!   analog) and to `run_naive` on all four model presets, on the
//!   dispatched *and* the scalar-forced micro-kernel backends (CI also
//!   re-runs this whole file under `GRIM_FORCE_SCALAR=1` and
//!   `GRIM_FORCE_UNPACKED=1`);
//! * the static nnz-balanced `WorkPartition` assigns every nonzero
//!   exactly once, and on a sparsity-skewed fixture its max/min
//!   thread-nnz ratio stays ≤ 1.25 where the even row split is badly
//!   imbalanced;
//! * u16 delta index compression round-trips (and the u32 fallback
//!   engages for signature spans wider than u16).

use grim::compiler::packing::PackOptions;
use grim::compiler::passes::{compile, Backend, CompileOptions};
use grim::compiler::plan::{KernelImpl, Step};
use grim::engine::Engine;
use grim::gemm::bcrc_gemm::GemmParams;
use grim::gemm::pack::{pack_bcrc, CacheParams, PackOverrides};
use grim::gemm::simd;
use grim::gemm::simd::{HwConfig, Isa};
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::sparse::{Bcrc, BcrConfig, BcrMask};
use grim::tensor::Tensor;
use grim::util::Rng;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn opts(seed: u64) -> InitOptions {
    InitOptions { rate: 6.0, block: [4, 16], seed }
}

fn compiled(
    kind: ModelKind,
    o: InitOptions,
    copts: CompileOptions,
) -> grim::compiler::plan::ExecutionPlan {
    let module = build_model(kind, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    compile(&module, &weights, copts).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Packed is the default; the engine switch preserves the old path; both
/// are bit-identical to each other and to the naive interpreter on every
/// preset (CONV, residual, depthwise, FC, and GRU-gate GEMV coverage).
#[test]
fn packed_bit_identical_to_unpacked_and_naive_on_presets() {
    for (i, kind) in KINDS.iter().enumerate() {
        let o = opts(900 + i as u64);
        let packed_plan = compiled(*kind, o, CompileOptions::default());
        assert!(
            packed_plan.packing.enabled || grim::compiler::packing::force_unpacked(),
            "{kind:?}: packing must be on by default"
        );
        let unpacked_plan = compiled(*kind, o, CompileOptions::default().without_packing());
        assert!(!unpacked_plan.packing.enabled);
        let packed = Engine::new(packed_plan, 2);
        let unpacked = Engine::new(unpacked_plan, 2);
        let mut rng = Rng::new(0x9A00 + i as u64);
        for case in 0..3 {
            let x = input_for(&packed, &mut rng);
            let a = packed.run(&x).unwrap();
            let b = unpacked.run(&x).unwrap();
            assert_eq!(a, b, "{kind:?} case {case}: packed != unpacked");
            let naive = packed.run_naive(&x).unwrap();
            assert_eq!(a, naive, "{kind:?} case {case}: packed != naive");
        }
    }
}

/// The same parity must hold with the engine pinned to the scalar
/// micro-kernel table (the `GRIM_FORCE_SCALAR=1` analog, runnable
/// in-process without touching the environment).
#[test]
fn packed_parity_on_scalar_backend() {
    for (i, kind) in KINDS.iter().enumerate() {
        let o = opts(930 + i as u64);
        let packed = Engine::with_microkernels(
            compiled(*kind, o, CompileOptions::default()),
            2,
            simd::scalar(),
        );
        let unpacked = Engine::with_microkernels(
            compiled(*kind, o, CompileOptions::default().without_packing()),
            2,
            simd::scalar(),
        );
        let mut rng = Rng::new(0x9B00 + i as u64);
        let x = input_for(&packed, &mut rng);
        let a = packed.run(&x).unwrap();
        assert_eq!(a, unpacked.run(&x).unwrap(), "{kind:?}: scalar packed != unpacked");
        assert_eq!(a, packed.run_naive(&x).unwrap(), "{kind:?}: scalar packed != naive");
    }
}

/// The engine switch really does keep the encode-order path: no BCRC
/// kernel carries a packed layout when packing is disabled, and every
/// BCRC kernel carries one when it is enabled.
#[test]
fn packing_switch_controls_kernels() {
    let o = opts(960);
    for (copts, expect_packed) in [
        (CompileOptions::default(), true),
        (CompileOptions::default().without_packing(), false),
    ] {
        // Under GRIM_FORCE_UNPACKED=1 (a CI leg), even the default
        // options must leave kernels unpacked.
        let expect_packed = expect_packed && !grim::compiler::packing::force_unpacked();
        let plan = compiled(ModelKind::Vgg16, o, copts);
        let mut bcrc_layers = 0;
        for (_, step) in &plan.steps {
            let kernel = match step {
                Step::Conv { kernel, .. } | Step::Fc { kernel, .. } => kernel,
                _ => continue,
            };
            if let KernelImpl::Bcrc { gemm } = kernel {
                bcrc_layers += 1;
                assert_eq!(
                    gemm.packed.is_some(),
                    expect_packed,
                    "packed presence must follow the switch"
                );
                if let Some(p) = &gemm.packed {
                    p.validate_against(&gemm.enc).unwrap();
                }
            }
        }
        assert!(bcrc_layers > 0, "fixture must exercise BCRC layers");
    }
}

/// Custom pack threads flow through to the plan's schedule set (where
/// the partitions now live — beside the packed layouts, not inside).
#[test]
fn pack_threads_option_controls_buckets() {
    let o = opts(961);
    let copts = CompileOptions {
        pack: PackOptions { threads: 3, ..Default::default() },
        ..Default::default()
    };
    let plan = compiled(ModelKind::Vgg16, o, copts);
    if grim::compiler::packing::force_unpacked() {
        assert!(plan.schedules.is_empty(), "unpacked plans carry no schedules");
        return; // CI unpacked leg: nothing else to inspect
    }
    assert_eq!(plan.schedules.threads, 3);
    assert!(!plan.schedules.is_empty());
    for part in &plan.schedules.parts {
        assert_eq!(part.num_buckets(), 3);
    }
    for (_, step) in &plan.steps {
        if let Step::Conv { kernel: KernelImpl::Bcrc { gemm }, .. } = step {
            let p = gemm.packed.as_ref().expect("packed by default");
            let part = plan.schedules.get(gemm.sched).expect("kernel references a schedule");
            part.validate_covers(&p.groups).unwrap();
        }
    }
}

fn random_enc(seed: u64, m: usize, k: usize, rate: f64) -> Bcrc {
    let mut rng = Rng::new(seed);
    let gr = (m / 8).max(1);
    let gc = (k / 16).max(1);
    let mask = BcrMask::random(m, k, BcrConfig::new(gr, gc), rate, &mut rng);
    let mut w = Tensor::rand_uniform(&[m, k], 1.0, &mut rng);
    mask.apply(&mut w);
    Bcrc::from_masked(&w, &mask)
}

/// Partition coverage property: across random matrices, shapes, and
/// thread counts, every nonzero is assigned to exactly one bucket.
#[test]
fn partition_assigns_every_nnz_exactly_once() {
    for seed in 0..10u64 {
        let m = 32 + 16 * (seed as usize % 5);
        let k = 64 + 32 * (seed as usize % 3);
        let enc = random_enc(seed, m, k, 3.0 + seed as f64);
        for threads in [1usize, 2, 4, 8] {
            for n_hint in [1usize, 64] {
                let p = pack_bcrc(
                    &enc,
                    GemmParams::default(),
                    n_hint,
                    HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default()),
                    PackOverrides::default(),
                );
                let part = p.lpt_partition(threads);
                part.validate_covers(&p.groups)
                    .unwrap_or_else(|e| panic!("seed {seed} t={threads} n={n_hint}: {e}"));
                assert_eq!(part.total_nnz(), enc.nnz(), "seed {seed}");
            }
        }
    }
}

/// Skewed-sparsity fixture: the first block-rows stay dense while the
/// rest are heavily pruned, so an even row split concentrates nearly all
/// nnz on the first threads. The LPT partition must stay within 1.25×
/// max/min and beat the even split.
#[test]
fn skewed_fixture_balances_within_ratio() {
    let (m, k, threads) = (256usize, 256usize, 4usize);
    let mut rng = Rng::new(0xBA1A);
    let cfg = BcrConfig::new(8, 4);
    let mut mask = BcrMask::dense(m, k, cfg);
    // Blocks 2..8 of rows: prune 3 of 4 column blocks (rate 4x there).
    let block_c: Vec<u32> = (0..(k / 4) as u32).collect();
    for br in 2..8 {
        for bc in 1..4 {
            mask.prune_cols(br, bc, &block_c);
        }
    }
    let mut w = Tensor::rand_uniform(&[m, k], 1.0, &mut rng);
    mask.apply(&mut w);
    let enc = Bcrc::from_masked(&w, &mask);

    let p = pack_bcrc(
        &enc,
        GemmParams::default(),
        64,
        HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default()),
        PackOverrides::default(),
    );
    let part = p.lpt_partition(threads);
    part.validate_covers(&p.groups).unwrap();
    let lpt_ratio = part.imbalance();
    assert!(lpt_ratio <= 1.25, "LPT max/min thread-nnz ratio {lpt_ratio} > 1.25");

    // Even split over reordered rows (the pre-partition executor
    // behavior): per-chunk nnz from each row's signature width.
    let chunk = m.div_ceil(threads);
    let mut even = vec![0usize; threads];
    for (t, load) in even.iter_mut().enumerate() {
        for r in (t * chunk).min(m)..((t + 1) * chunk).min(m) {
            *load += enc.row_weights(r).len();
        }
    }
    let even_ratio =
        *even.iter().max().unwrap() as f64 / (*even.iter().min().unwrap()).max(1) as f64;
    assert!(
        even_ratio > lpt_ratio,
        "fixture must actually be skewed (even {even_ratio:.2} vs lpt {lpt_ratio:.2})"
    );
}

/// u16 index compression round-trips exactly; matrices whose signature
/// span exceeds u16 fall back to u32 and still round-trip.
#[test]
fn index_compression_round_trips() {
    // Narrow matrix: must select u16 and decode identically.
    let enc = random_enc(42, 64, 96, 5.0);
    let p = pack_bcrc(
        &enc,
        GemmParams::default(),
        32,
        HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default()),
        PackOverrides::default(),
    );
    assert!(p.is_u16());
    p.validate_against(&enc).unwrap();
    for gi in 0..p.groups.len() {
        let view = p.group_cols(gi);
        for i in 0..view.len() {
            assert!(view.at(i) < enc.cols);
        }
    }

    // Wide hand-built matrix (span > u16::MAX): u32 fallback.
    let wide = Bcrc {
        rows: 3,
        cols: 80_000,
        reorder: vec![2, 0, 1],
        row_offset: vec![0, 2, 4, 6],
        occurrence: vec![0, 3],
        col_stride: vec![0, 2],
        compact_col: vec![5, 79_321],
        weights: vec![0.5, -1.0, 2.0, 0.25, -0.75, 1.5],
    };
    wide.validate().unwrap();
    let pw = pack_bcrc(
        &wide,
        GemmParams::default(),
        1,
        HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default()),
        PackOverrides::default(),
    );
    assert!(!pw.is_u16(), "span > u16::MAX must fall back to u32");
    pw.validate_against(&wide).unwrap();
}

/// Cross-backend sanity doesn't regress with packing on: all four
/// compile backends still agree on the same masked weights.
#[test]
fn backends_still_agree_with_packing() {
    let o = opts(975);
    let module = build_model(ModelKind::Resnet18, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    let mut rng = Rng::new(0x975);
    let mut shared_x: Option<Tensor> = None;
    let mut outputs: Vec<(Backend, Tensor)> = Vec::new();
    for b in [Backend::Grim, Backend::NaiveDense, Backend::OptDense, Backend::CsrSparse] {
        let plan = compile(&module, &weights, CompileOptions::for_backend(b)).unwrap();
        let engine = Engine::new(plan, 2);
        let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
        let x = shared_x
            .get_or_insert_with(|| Tensor::rand_uniform(&dims, 1.0, &mut rng))
            .clone();
        outputs.push((b, engine.run(&x).unwrap()));
    }
    let (b0, ref0) = &outputs[0];
    for (b, o) in &outputs[1..] {
        assert!(
            o.allclose(ref0, 1e-3, 1e-3),
            "{b:?} disagrees with {b0:?}: {}",
            o.max_abs_diff(ref0)
        );
    }
}
