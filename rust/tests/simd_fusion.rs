//! SIMD-dispatch + epilogue-fusion acceptance tests:
//!
//! * scalar and dispatched micro-kernels agree (≤1e-5) on random GEMM
//!   shapes including remainder lanes;
//! * the fused+SIMD planned path is bit-identical to `run_naive` on all
//!   four model presets, and the scalar backend is force-selectable
//!   (engine-level and layer-level) with outputs matching to 1e-4;
//! * fused-epilogue plans are bit-identical to unfused plans on all four
//!   presets, and fusion provably shrinks the activation arena;
//! * `Flatten` in-place elision aliases the producer's buffer without
//!   changing outputs.

use grim::compiler::passes::{compile, CompileOptions};
use grim::compiler::plan::Step;
use grim::engine::Engine;
use grim::gemm::bcrc_gemm::{BcrcGemm, GemmParams};
use grim::gemm::simd;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::sparse::{Bcrc, BcrConfig, BcrMask};
use grim::tensor::Tensor;
use grim::util::Rng;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn opts(seed: u64) -> InitOptions {
    InitOptions { rate: 6.0, block: [4, 16], seed }
}

fn compiled(kind: ModelKind, o: InitOptions, copts: CompileOptions) -> grim::compiler::plan::ExecutionPlan {
    let module = build_model(kind, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    compile(&module, &weights, copts).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Property: scalar vs dispatched backends agree within 1e-5 at the GEMM
/// level on random shapes, including ones that leave SIMD remainder lanes
/// (dims deliberately not multiples of the vector width).
#[test]
fn prop_scalar_vs_simd_gemm_within_1e5() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0x51F0 + seed);
        let m = 8 + rng.index(57); // 8..=64, rarely 8-aligned
        let k = 16 + rng.index(113);
        let n = 1 + rng.index(37);
        let gr = (m / 4).max(1);
        let gc = (k / 8).max(1);
        let mask = BcrMask::random(m, k, BcrConfig::new(gr, gc), 3.0, &mut rng);
        let mut w = Tensor::rand_uniform(&[m, k], 0.5, &mut rng);
        mask.apply(&mut w);
        let enc = Bcrc::from_masked(&w, &mask);
        let x = Tensor::rand_uniform(&[k, n], 0.5, &mut rng);
        let fast = BcrcGemm::new(enc.clone(), GemmParams::default()).execute(&x);
        let slow =
            BcrcGemm::new(enc, GemmParams { simd: false, ..Default::default() }).execute(&x);
        assert!(
            fast.allclose(&slow, 1e-5, 1e-5),
            "seed {seed} m={m} k={k} n={n}: maxdiff={}",
            fast.max_abs_diff(&slow)
        );
    }
}

/// The fused+SIMD planned path must be bit-identical to the naive
/// reference interpreter on every preset; the same must hold for an
/// engine pinned to the scalar backend, whose output must in turn match
/// the SIMD engine's to 1e-4 (FMA rounding is the only difference).
#[test]
fn fused_simd_planned_matches_naive_and_scalar_forceable() {
    for (i, kind) in KINDS.iter().enumerate() {
        let o = opts(500 + i as u64);
        let simd_eng = Engine::new(compiled(*kind, o, CompileOptions::default()), 2);
        let scalar_eng = Engine::with_microkernels(
            compiled(*kind, o, CompileOptions::default()),
            2,
            simd::scalar(),
        );
        assert!(std::ptr::eq(scalar_eng.microkernels(), simd::scalar()));
        let mut rng = Rng::new(0x5F00 + i as u64);
        for case in 0..3 {
            let x = input_for(&simd_eng, &mut rng);
            let planned = simd_eng.run(&x).unwrap();
            let naive = simd_eng.run_naive(&x).unwrap();
            assert_eq!(planned, naive, "{kind:?} case {case}: fused planned != naive");

            let planned_sc = scalar_eng.run(&x).unwrap();
            let naive_sc = scalar_eng.run_naive(&x).unwrap();
            assert_eq!(planned_sc, naive_sc, "{kind:?} case {case}: scalar planned != naive");

            assert!(
                planned.allclose(&planned_sc, 1e-4, 1e-4),
                "{kind:?} case {case}: scalar vs simd diverged ({})",
                planned.max_abs_diff(&planned_sc)
            );
        }
    }
}

/// Per-layer scalar pinning (`GemmParams::simd=false` via the IR `simd`
/// gene) must compile and stay bit-identical planned-vs-naive.
#[test]
fn layer_level_scalar_pin_via_ir() {
    let o = opts(640);
    let mut module = build_model(ModelKind::Vgg16, Preset::CifarMini, o);
    for ir in &mut module.irs {
        ir.simd = false;
    }
    let weights = random_weights(&module, o);
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    let engine = Engine::new(plan, 2);
    let mut rng = Rng::new(0x640);
    let x = input_for(&engine, &mut rng);
    assert_eq!(engine.run(&x).unwrap(), engine.run_naive(&x).unwrap());
}

/// Fused plans must produce exactly the unfused plans' outputs on all
/// four presets (fusion is a pure scheduling change).
#[test]
fn fused_bit_identical_to_unfused_all_presets() {
    for (i, kind) in KINDS.iter().enumerate() {
        let o = opts(700 + i as u64);
        let fused = Engine::new(compiled(*kind, o, CompileOptions::default()), 2);
        let unfused = Engine::new(
            compiled(*kind, o, CompileOptions { fuse: false, ..Default::default() }),
            2,
        );
        let mut rng = Rng::new(0x7F00 + i as u64);
        for case in 0..3 {
            let x = input_for(&fused, &mut rng);
            let a = fused.run(&x).unwrap();
            let b = unfused.run(&x).unwrap();
            assert_eq!(a, b, "{kind:?} case {case}: fused != unfused");
        }
    }
}

/// Fusion must delete buffers from the memory plan (folded ReLU steps
/// lose their value buffer) and provably shrink the arena on at least
/// one preset. MobileNet-V2 is the guaranteed case: its 1×1 convs carry
/// no im2col scratch, so the unfused `expand → ReLU6` pair (two live
/// copies of the widest expanded activation) *is* the arena peak, and
/// folding the ReLU6 removes one of the copies. On VGG/ResNet the peak
/// sits at a conv's im2col scratch, so fusion may leave the arena size
/// unchanged — but never meaningfully larger.
#[test]
fn fusion_shrinks_memory_plan() {
    let mut any_smaller = false;
    for (i, kind) in KINDS.iter().enumerate() {
        let o = opts(800 + i as u64);
        let fused = compiled(*kind, o, CompileOptions::default()).memory;
        let unfused = compiled(*kind, o, CompileOptions { fuse: false, ..Default::default() }).memory;
        if *kind != ModelKind::Gru {
            assert!(
                fused.buffers.len() < unfused.buffers.len(),
                "{kind:?}: fusion did not remove any buffer ({} vs {})",
                fused.buffers.len(),
                unfused.buffers.len()
            );
        }
        if fused.arena_bytes() < unfused.arena_bytes() {
            any_smaller = true;
        }
        assert!(
            fused.arena_bytes() <= unfused.arena_bytes() * 11 / 10,
            "{kind:?}: fused arena grew pathologically ({} vs {})",
            fused.arena_bytes(),
            unfused.arena_bytes()
        );
    }
    assert!(any_smaller, "no preset's arena shrank under fusion");

    // MobileNet specifically: the fused arena must be strictly smaller.
    let o = opts(900);
    let plan = compiled(ModelKind::MobilenetV2, o, CompileOptions::default());
    let unfused =
        compiled(ModelKind::MobilenetV2, o, CompileOptions { fuse: false, ..Default::default() });
    assert!(
        plan.memory.arena_bytes() < unfused.memory.arena_bytes(),
        "mobilenet fused arena {} must be < unfused {}",
        plan.memory.arena_bytes(),
        unfused.memory.arena_bytes()
    );

    // ResNet specifically: Add→ReLU now folds — at least one Add step
    // must carry a fused activation (its ReLU's buffer is gone).
    let rplan = compiled(ModelKind::Resnet18, o, CompileOptions::default());
    let fused_adds = rplan
        .steps
        .iter()
        .filter(|(_, s)| {
            matches!(s, Step::Add { act } if *act != grim::compiler::plan::Activation::None)
        })
        .count();
    assert!(fused_adds > 0, "no Add step got a fused activation");
}

/// Flatten in-place elision: a single-consumer Flatten must alias its
/// producer's buffer (same arena range, no extra buffer) and leave
/// outputs bit-identical to the naive interpreter.
#[test]
fn flatten_aliases_producer_buffer() {
    for kind in [ModelKind::Vgg16, ModelKind::Resnet18] {
        let o = opts(950);
        let plan = compiled(kind, o, CompileOptions::default());
        let mut found = false;
        for (id, step) in &plan.steps {
            if matches!(step, Step::Flatten) {
                let src = plan.inputs[*id][0];
                assert_eq!(
                    plan.memory.value_range(*id),
                    plan.memory.value_range(src),
                    "{kind:?}: Flatten node {id} did not alias its producer"
                );
                found = true;
            }
        }
        assert!(found, "{kind:?}: no Flatten step found");
        let engine = Engine::new(plan, 2);
        let mut rng = Rng::new(0x950);
        let x = input_for(&engine, &mut rng);
        assert_eq!(engine.run(&x).unwrap(), engine.run_naive(&x).unwrap(), "{kind:?}");
    }
}
