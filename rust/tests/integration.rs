//! Cross-module integration tests: DSL→compile→execute equivalence,
//! python↔rust bridges (.grim and HLO artifacts), and the serving loop.
//! Bridge tests skip (with a notice) when `make artifacts` /
//! `make train-demo` outputs are absent, so `cargo test` works on a fresh
//! checkout.

use grim::compiler::passes::{compile, Backend, CompileOptions};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::tensor::Tensor;
use grim::util::Rng;
use std::path::Path;

fn opts(rate: f64, seed: u64) -> InitOptions {
    InitOptions { rate, block: [4, 16], seed }
}

/// Full pipeline over every zoo model: all backends agree numerically.
#[test]
fn zoo_backends_agree_end_to_end() {
    for kind in [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru] {
        let o = opts(6.0, 77);
        let module = build_model(kind, Preset::CifarMini, o);
        let weights = random_weights(&module, o);
        let shapes = module.graph.infer_shapes().unwrap();
        let dims = shapes[module.graph.input().unwrap()].dims().to_vec();
        let mut rng = Rng::new(kind as u64);
        let x = Tensor::rand_uniform(&dims, 1.0, &mut rng);
        let mut outs = Vec::new();
        for b in [Backend::Grim, Backend::NaiveDense, Backend::CsrSparse] {
            let plan = compile(&module, &weights, CompileOptions::for_backend(b)).unwrap();
            outs.push(Engine::new(plan, 4).run(&x).unwrap());
        }
        for o2 in &outs[1..] {
            assert!(
                outs[0].allclose(o2, 1e-3, 1e-3),
                "{kind:?}: backend divergence {}",
                outs[0].max_abs_diff(o2)
            );
        }
    }
}

/// .grim round trip through disk preserves inference results exactly.
#[test]
fn grim_file_round_trip_preserves_inference() {
    let o = opts(8.0, 13);
    let module = build_model(ModelKind::Vgg16, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    let tmp = std::env::temp_dir().join("grim_integration_rt.grim");
    grim::formats::save_grim(&tmp, &module, &weights).unwrap();
    let (m2, w2) = grim::formats::load_grim(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();

    let mut rng = Rng::new(3);
    let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
    let a = Engine::new(compile(&module, &weights, CompileOptions::default()).unwrap(), 2)
        .run(&x)
        .unwrap();
    let b = Engine::new(compile(&m2, &w2, CompileOptions::default()).unwrap(), 2)
        .run(&x)
        .unwrap();
    assert_eq!(a, b, "round-tripped model must be bit-identical in behaviour");
}

/// Load the python-trained model if present (make train-demo).
#[test]
fn python_grim_file_loads_and_runs() {
    let path = Path::new("artifacts/demo_cnn.grim");
    if !path.exists() {
        eprintln!("SKIP python_grim_file_loads_and_runs: run `make train-demo`");
        return;
    }
    let (module, weights) = grim::formats::load_grim(path).unwrap();
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    let engine = Engine::new(plan, 2);
    let mut rng = Rng::new(5);
    let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
    let out = engine.run(&x).unwrap();
    assert_eq!(out.numel(), 10);
    let sum: f32 = out.data().iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "softmax output must normalize");
    // sparse layers really are sparse
    let nnz_frac: f64 = weights
        .values()
        .filter(|lw| lw.mask.is_some())
        .map(|lw| 1.0 - lw.w.zero_fraction())
        .sum::<f64>()
        / weights.values().filter(|lw| lw.mask.is_some()).count().max(1) as f64;
    assert!(nnz_frac < 0.5, "trained model should be majority-pruned, got nnz {nnz_frac}");
}

/// The jax->HLO-text->PJRT bridge with known numerics (make artifacts).
#[test]
fn hlo_bridge_numerics() {
    let store = grim::runtime::ArtifactStore::default_dir();
    if !store.exists("bridge_check") {
        eprintln!("SKIP hlo_bridge_numerics: run `make artifacts`");
        return;
    }
    let model = store.load("bridge_check").unwrap();
    let x = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
    let y = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
    let out = model.run(&[x, y]).unwrap();
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(out[0], vec![5.0, 5.0, 9.0, 9.0]);
}

/// The Pallas-lowered BCR kernel artifact compiles and executes on the
/// rust PJRT client (shape check; weights are baked at export time).
#[test]
fn pallas_kernel_artifact_executes() {
    let store = grim::runtime::ArtifactStore::default_dir();
    if !store.exists("bcr_gemm_256x512") {
        eprintln!("SKIP pallas_kernel_artifact_executes: run `make artifacts`");
        return;
    }
    let model = store.load("bcr_gemm_256x512").unwrap();
    let mut rng = Rng::new(6);
    let x = Tensor::rand_uniform(&[512, 32], 1.0, &mut rng);
    let out = model.run(&[x]).unwrap();
    assert_eq!(out[0].len(), 256 * 32);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

/// Serving loop correctness under load with the full CNN plan.
#[test]
fn server_under_concurrent_load() {
    use grim::coordinator::{Server, ServerConfig};
    let o = opts(8.0, 21);
    let module = build_model(ModelKind::Resnet18, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    let server = std::sync::Arc::new(Server::start(Engine::new(plan, 4), ServerConfig::default()));
    let mut handles = Vec::new();
    for t in 0..3 {
        let s = std::sync::Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + t);
            for _ in 0..8 {
                let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
                let resp = s.infer(x).unwrap();
                assert_eq!(resp.output.numel(), 10);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.stats().completed, 24);
}

/// The tuner improves (or at least never worsens) a real layer's latency
/// versus the default configuration.
///
/// Ignored by default: the assertion compares wall-clock timings, which
/// is genuinely host-dependent — a noisy/overcommitted CI box can make
/// the tuned configuration look slower than the default without any code
/// being wrong. Run explicitly with `cargo test -- --ignored` on a quiet
/// machine.
#[test]
#[ignore = "wall-clock comparison; host-dependent (run with --ignored on a quiet machine)"]
fn tuner_never_worsens_layer() {
    use grim::gemm::bcrc_gemm::{BcrcGemm, GemmParams};
    use grim::sparse::{Bcrc, BcrConfig, BcrMask};
    use grim::tuner::{tune_layer, GaConfig, SearchSpace};
    use grim::util::timer;

    let mut rng = Rng::new(31);
    let (rows, cols) = (256, 512);
    let mask = BcrMask::random(rows, cols, BcrConfig::from_block_size(rows, cols, 4, 16), 8.0, &mut rng);
    let mut w = Tensor::rand_uniform(&[rows, cols], 0.4, &mut rng);
    mask.apply(&mut w);
    let enc = Bcrc::from_masked(&w, &mask);
    let x = Tensor::rand_uniform(&[cols, 32], 1.0, &mut rng);

    let default_ms = timer::time_median_ms(5, 1, || {
        let g = BcrcGemm::new(enc.clone(), GemmParams::default());
        std::hint::black_box(g.execute(&x));
    });
    let ga = GaConfig { population: 6, generations: 3, eval_iters: 3, ..Default::default() };
    let res = tune_layer(&SearchSpace::default(), ga, |cfg| {
        let g = BcrcGemm::new(enc.clone(), cfg.gemm_params());
        std::hint::black_box(g.execute(&x));
    });
    assert!(
        res.best_ms <= default_ms * 1.5,
        "tuned {} ms should not be far above default {} ms",
        res.best_ms,
        default_ms
    );
}
