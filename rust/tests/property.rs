//! Property-based tests (own harness, seeded xoshiro PRNG — no proptest in
//! the vendored dep set). Each property runs across many random cases;
//! failures print the seed for exact replay.

use grim::conv::im2col::{dead_columns, im2col, im2col_skip, weights_to_gemm, ConvGeom};
use grim::conv::{conv2d_direct, winograd::conv2d_winograd};
use grim::gemm::bcrc_gemm::{BcrcGemm, GemmParams};
use grim::gemm::naive::{naive_gemm, naive_gemm_dense};
use grim::gemm::tiled::{tiled_gemm, TileParams};
use grim::gemm::{csr_gemm, loadcount};
use grim::graph::dsl;
use grim::sparse::{Bcrc, BcrConfig, BcrMask, Csr, ReorderPlan};
use grim::tensor::Tensor;
use grim::util::Rng;

const CASES: u64 = 25;

fn random_mask(rng: &mut Rng) -> (BcrMask, Tensor) {
    let dims = [(16usize, 32usize, 4usize, 4usize), (32, 64, 4, 16), (8, 16, 2, 8), (64, 48, 8, 4)];
    let (rows, cols, br, bc) = dims[rng.index(dims.len())];
    let rate = 1.5 + rng.f64() * 10.0;
    let cfg = BcrConfig::from_block_size(rows, cols, br, bc);
    let mask = BcrMask::random(rows, cols, cfg, rate, rng);
    let mut w = Tensor::rand_uniform(&[rows, cols], 1.0, rng);
    mask.apply(&mut w);
    (mask, w)
}

/// Property: BCRC encode∘decode is the identity on masked weights, and the
/// encoding always validates structurally.
#[test]
fn prop_bcrc_round_trip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xA000 + seed);
        let (mask, w) = random_mask(&mut rng);
        let enc = Bcrc::from_masked(&w, &mask);
        enc.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(enc.decode(), w, "seed {seed}");
        assert_eq!(enc.nnz(), mask.nnz(), "seed {seed}");
    }
}

/// Property: every sparse/dense kernel computes the same product.
#[test]
fn prop_all_kernels_agree() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xB000 + seed);
        let (mask, w) = random_mask(&mut rng);
        let n = 1 + rng.index(17);
        let x = Tensor::rand_uniform(&[mask.cols, n], 1.0, &mut rng);
        let oracle = naive_gemm(&w, &x);

        let dense = naive_gemm_dense(&w, &x);
        assert!(dense.allclose(&oracle, 1e-4, 1e-4), "dense seed {seed}");

        let tiled = tiled_gemm(&w, &x, TileParams { mr: 4, kc: 32, nc: 16 });
        assert!(tiled.allclose(&oracle, 1e-3, 1e-3), "tiled seed {seed}");

        let csr = csr_gemm(&Csr::from_dense(&w), &x);
        assert!(csr.allclose(&oracle, 1e-3, 1e-3), "csr seed {seed}");

        let params = GemmParams {
            unroll: [1usize, 2, 4, 8][rng.index(4)],
            n_tile: [8usize, 64, 1024][rng.index(3)],
            lre: rng.chance(0.7),
            simd: rng.chance(0.5),
        };
        let grim = BcrcGemm::new(Bcrc::from_masked(&w, &mask), params).execute(&x);
        assert!(grim.allclose(&oracle, 1e-3, 1e-3), "bcrc seed {seed} {params:?}");
    }
}

/// Property: reorder is a bijection, never increases divergence, and the
/// reordered execution equals the identity-order execution.
#[test]
fn prop_reorder_safety() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xC000 + seed);
        let (mask, w) = random_mask(&mut rng);
        let plan = ReorderPlan::from_mask(&mask);
        assert!(plan.is_permutation(), "seed {seed}");
        let sigs: Vec<Vec<u32>> = (0..mask.rows).map(|r| mask.row_columns(r)).collect();
        let ident = ReorderPlan::identity(sigs, mask.rows, mask.cols);
        assert!(plan.divergence(8) <= ident.divergence(8), "seed {seed}");

        let x = Tensor::rand_uniform(&[mask.cols, 4], 1.0, &mut rng);
        let a = BcrcGemm::new(Bcrc::encode(&w, &mask, &plan), GemmParams::default()).execute(&x);
        let b = BcrcGemm::new(Bcrc::encode(&w, &mask, &ident), GemmParams::default()).execute(&x);
        assert!(a.allclose(&b, 1e-4, 1e-4), "seed {seed}");
    }
}

/// Property: BCRC never stores more column indices than CSR, and the two
/// encodings agree on nnz.
#[test]
fn prop_bcrc_index_no_worse_than_csr() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xD000 + seed);
        let (mask, w) = random_mask(&mut rng);
        let enc = Bcrc::from_masked(&w, &mask);
        let csr = Csr::from_dense(&w);
        assert_eq!(enc.nnz(), csr.nnz(), "seed {seed}");
        assert!(enc.compact_col.len() <= csr.col_idx.len(), "seed {seed}");
    }
}

/// Property: analytic LRE load counts are bounded: no-LRE equals nnz*n,
/// LRE reduction never exceeds the unroll factor, and is ≥ 1.
#[test]
fn prop_loadcount_bounds() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0xE000 + seed);
        let (mask, w) = random_mask(&mut rng);
        let enc = Bcrc::from_masked(&w, &mask);
        let n = 1 + rng.index(40);
        for u in [2usize, 4, 8] {
            let no = loadcount::bcrc_input_loads(&enc, n, 1, false);
            let yes = loadcount::bcrc_input_loads(&enc, n, u, true);
            assert_eq!(no, enc.nnz() as u64 * n as u64);
            assert!(yes <= no, "seed {seed} u={u}");
            assert!(yes * u as u64 >= no, "seed {seed} u={u}: reduction beyond unroll");
        }
    }
}

/// Property: im2col+GEMM == direct convolution == Winograd (3x3/s1) for
/// random geometries.
#[test]
fn prop_conv_lowering_equivalence() {
    for seed in 0..15 {
        let mut rng = Rng::new(0xF000 + seed);
        let in_c = 1 + rng.index(4);
        let hw = 5 + rng.index(8);
        let out_c = 1 + rng.index(6);
        let stride = 1 + rng.index(2);
        let pad = rng.index(2);
        let g = ConvGeom { in_c, in_h: hw, in_w: hw, out_c, kh: 3, kw: 3, stride, pad };
        if g.in_h + 2 * pad < 3 {
            continue;
        }
        let w = Tensor::rand_uniform(&[out_c, in_c, 3, 3], 1.0, &mut rng);
        let x = Tensor::rand_uniform(&[in_c, hw, hw], 1.0, &mut rng);
        let direct = conv2d_direct(&x, &w, stride, pad);
        let gemm = naive_gemm(&weights_to_gemm(&w), &im2col(&x, &g))
            .reshape(&[out_c, g.out_h(), g.out_w()]);
        assert!(gemm.allclose(&direct, 1e-3, 1e-3), "seed {seed} im2col");
        if stride == 1 {
            let wino = conv2d_winograd(&x, &w, pad);
            assert!(wino.allclose(&direct, 1e-3, 1e-3), "seed {seed} winograd");
        }
    }
}

/// Property: im2col dead-column skipping never changes the product.
#[test]
fn prop_im2col_skip_equivalence() {
    for seed in 0..15 {
        let mut rng = Rng::new(0x1F00 + seed);
        let g = ConvGeom { in_c: 3, in_h: 8, in_w: 8, out_c: 4, kh: 3, kw: 3, stride: 1, pad: 1 };
        let mut w = Tensor::rand_uniform(&[4, 27], 1.0, &mut rng);
        // randomly kill some full columns
        for c in 0..27 {
            if rng.chance(0.3) {
                for r in 0..4 {
                    *w.at2_mut(r, c) = 0.0;
                }
            }
        }
        let dead = dead_columns(&w);
        let x = Tensor::rand_uniform(&[3, 8, 8], 1.0, &mut rng);
        let full = naive_gemm(&w, &im2col(&x, &g));
        let skip = naive_gemm(&w, &im2col_skip(&x, &g, &dead));
        assert!(full.allclose(&skip, 1e-5, 1e-5), "seed {seed}");
    }
}

/// Property: DSL print∘parse is the identity on randomly generated
/// programs (graph fuzzing).
#[test]
fn prop_dsl_round_trip_fuzz() {
    for seed in 0..20 {
        let mut rng = Rng::new(0x2F00 + seed);
        let mut text = String::from("model \"fuzz\"\nin = Input(shape=[3,16,16])\n");
        let mut prev = "in".to_string();
        let mut c = 3usize;
        let layers = 1 + rng.index(6);
        for i in 0..layers {
            let name = format!("n{i}");
            match rng.index(4) {
                0 => {
                    let oc = 1 + rng.index(8);
                    text.push_str(&format!(
                        "{name} = Conv2D({prev}, out_c={oc}, kh=3, kw=3, stride=1, pad=1)\n"
                    ));
                    c = oc;
                }
                1 => text.push_str(&format!("{name} = ReLU({prev})\n")),
                2 => text.push_str(&format!("{name} = ReLU6({prev})\n")),
                _ => {
                    text.push_str(&format!(
                        "{name} = DWConv2D({prev}, kh=3, kw=3, stride=1, pad=1)\n"
                    ));
                }
            }
            prev = name;
        }
        let _ = c;
        text.push_str(&format!("f = Flatten({prev})\nfc = FC(f, out_f=10)\n"));
        let m = dsl::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        let printed = dsl::print(&m);
        let m2 = dsl::parse(&printed).unwrap();
        assert_eq!(m.graph.len(), m2.graph.len(), "seed {seed}");
        for (a, b) in m.graph.nodes().iter().zip(m2.graph.nodes()) {
            assert_eq!(a.op, b.op, "seed {seed}");
            assert_eq!(a.inputs, b.inputs, "seed {seed}");
        }
        // shapes must infer on every fuzzed graph
        m.graph.infer_shapes().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Failure injection: corrupted .grim files must be rejected, never
/// mis-loaded.
#[test]
fn prop_grim_file_corruption_rejected() {
    use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
    let opts = InitOptions { rate: 4.0, block: [4, 16], seed: 55 };
    let module = build_model(ModelKind::Gru, Preset::TimitMini, opts);
    let weights = random_weights(&module, opts);
    let tmp = std::env::temp_dir().join("grim_prop_corrupt.grim");
    grim::formats::save_grim(&tmp, &module, &weights).unwrap();
    let good = std::fs::read(&tmp).unwrap();
    let mut rng = Rng::new(77);
    let mut rejected = 0;
    for _ in 0..20 {
        let mut bad = good.clone();
        match rng.index(3) {
            0 => {
                // truncate
                let cut = 8 + rng.index(bad.len() - 16);
                bad.truncate(cut);
            }
            1 => {
                // flip bytes in the header/structure region
                let i = rng.index(64.min(bad.len()));
                bad[i] ^= 0xFF;
            }
            _ => {
                // garbage tail
                bad.extend_from_slice(&[0xDE, 0xAD, 0xBE, 0xEF]);
            }
        }
        std::fs::write(&tmp, &bad).unwrap();
        match grim::formats::load_grim(&tmp) {
            Err(_) => rejected += 1,
            Ok((m, w)) => {
                // byte flips inside weight payloads can legitimately load;
                // but the structure must still be coherent
                assert_eq!(m.graph.len(), module.graph.len());
                assert_eq!(w.len(), weights.len());
            }
        }
    }
    assert!(rejected >= 10, "corruption detection too weak: {rejected}/20");
    std::fs::remove_file(&tmp).ok();
}

/// Property: the mask generator hits its requested pruning rate within a
/// factor band and produces signature sharing (the structural property
/// BCRC depends on).
#[test]
fn prop_mask_rate_and_sharing() {
    for seed in 0..CASES {
        let mut rng = Rng::new(0x3F00 + seed);
        let rate = 2.0 + rng.f64() * 14.0;
        let mask = BcrMask::random(128, 128, BcrConfig::from_block_size(128, 128, 4, 16), rate, &mut rng);
        let achieved = mask.pruning_rate();
        assert!(
            achieved > rate * 0.45 && achieved < rate * 2.2,
            "seed {seed}: rate {rate} achieved {achieved}"
        );
        let plan = ReorderPlan::from_mask(&mask);
        assert!(
            plan.num_groups() < mask.rows,
            "seed {seed}: no signature sharing at all ({} groups / {} rows)",
            plan.num_groups(),
            mask.rows
        );
    }
}
