//! Register-tiled microkernel parity suite.
//!
//! The regtile path (one mr×n_tile block of C held in accumulators
//! across a whole kc panel, epilogue applied in-register on the final
//! K block) must be **bit-identical** to the unpacked axpy-through-
//! memory path for every panel height 1..=max_mr, every j-tail shape
//! (full vectors, one vector, scalar tail), degenerate kc, and every
//! hardware-matrix row — on the dispatched vtable *and* the scalar
//! table. CI re-runs this file under `GRIM_FORCE_AXPY=1`, where the
//! same assertions pin the packed axpy fallback instead; the oversized-
//! mr test exercises that fallback in-process regardless of the
//! environment.

use grim::gemm::bcrc_gemm::{BcrcGemm, GemmParams};
use grim::quant;
use grim::gemm::pack::{pack_bcrc, CacheParams, PackOverrides, PackedDense};
use grim::gemm::simd::{self, HwConfig, Isa};
use grim::gemm::tiled::{tiled_gemm_into_ep, tiled_gemm_packed_into_ep, TileParams};
use grim::gemm::Epilogue;
use grim::sparse::{Bcrc, BcrConfig, BcrMask};
use grim::tensor::Tensor;
use grim::util::{Rng, ThreadPool};
use std::sync::Arc;

fn random_enc(seed: u64, m: usize, k: usize, rate: f64) -> Bcrc {
    let mut rng = Rng::new(seed);
    let gr = (m / 4).max(1);
    let gc = (k / 8).max(1);
    let mask = BcrMask::random(m, k, BcrConfig::new(gr, gc), rate, &mut rng);
    let mut w = Tensor::rand_uniform(&[m, k], 1.0, &mut rng);
    mask.apply(&mut w);
    Bcrc::from_masked(&w, &mask)
}

fn rand_x(seed: u64, k: usize, n: usize) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::rand_uniform(&[k, n], 1.0, &mut rng)
}

fn rand_bias(seed: u64, m: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    Tensor::rand_uniform(&[m], 1.0, &mut rng).data().to_vec()
}

/// Run packed (regtile) and unpacked (axpy) BCRC GEMM on identical
/// inputs and assert bit-equality, on both kernel tables.
#[allow(clippy::too_many_arguments)]
fn assert_bcrc_parity(
    enc: &Bcrc,
    params: GemmParams,
    hw: HwConfig,
    ov: PackOverrides,
    n: usize,
    ep_bias: Option<&[f32]>,
    seed: u64,
    what: &str,
) {
    let p = pack_bcrc(enc, params, n, hw, ov);
    p.validate_against(enc).unwrap_or_else(|e| panic!("{what}: {e}"));
    let packed = BcrcGemm::new(enc.clone(), params).with_packed(Arc::new(p));
    let plain = BcrcGemm::new(enc.clone(), params);
    let x = rand_x(seed, enc.cols, n);
    let eps = [
        Epilogue::None,
        Epilogue::Relu,
        match ep_bias {
            Some(b) => Epilogue::BiasRelu6(b),
            None => Epilogue::Relu6,
        },
    ];
    for mk in [simd::active(), simd::scalar()] {
        for ep in eps {
            let mut a = vec![0.0f32; enc.rows * n];
            let mut b = vec![0.0f32; enc.rows * n];
            let mut gather = vec![0.0f32; enc.max_group_cols()];
            packed.execute_into_ep(x.data(), n, &mut a, &mut gather, mk, ep);
            plain.execute_into_ep(x.data(), n, &mut b, &mut gather, mk, ep);
            assert_eq!(a, b, "{what} [{} ep={ep:?}]: packed != unpacked", mk.name);
        }
    }
}

/// Every panel height the dispatch guard admits (1..=max_mr) must be
/// bit-identical to the axpy path, across remainder-heavy shapes.
#[test]
fn panel_heights_sweep_bitwise() {
    let max_mr = simd::active().tile.max_mr;
    assert!(max_mr >= 1, "tile must admit at least scalar panels");
    for mr in 1..=max_mr {
        for (m, k, n) in [(7usize, 32usize, 5usize), (24, 48, 16), (36, 64, 17)] {
            let enc = random_enc(0x51EE + mr as u64, m, k, 4.0);
            let bias = rand_bias(0xB1A5 + mr as u64, m);
            assert_bcrc_parity(
                &enc,
                GemmParams::default(),
                HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default()),
                PackOverrides { kc: 0, mc: 0, mr },
                n,
                Some(&bias),
                0x11AA + mr as u64,
                &format!("mr={mr} m={m} k={k} n={n}"),
            );
        }
    }
}

/// Degenerate cache blocks: kc=1 (one K step per panel, epilogue fires
/// on every block boundary decision), tiny mc, and n tails of every
/// flavor (sub-vector, one-vector, vector+scalar remainder).
#[test]
fn degenerate_blocks_and_n_tails() {
    let enc = random_enc(0xDE6E, 24, 64, 5.0);
    let bias = rand_bias(0xDE61, 24);
    for kc in [1usize, 2, 5] {
        for n in [2usize, 3, 8, 15, 16, 17, 33] {
            assert_bcrc_parity(
                &enc,
                GemmParams { n_tile: 16, ..GemmParams::default() },
                HwConfig::for_isa(Isa::Avx512f, CacheParams::default()),
                PackOverrides { kc, mc: 8, mr: 0 },
                n,
                Some(&bias),
                0x22BB + (kc * 100 + n) as u64,
                &format!("kc={kc} n={n}"),
            );
        }
    }
}

/// Every hardware-matrix row's prescribed (mr, blocking) stays
/// bit-identical — layouts packed *for* another ISA still run correctly
/// on this host's kernels (the guard only checks mr <= max_mr).
#[test]
fn hardware_matrix_rows_all_parity() {
    let enc = random_enc(0x15A0, 40, 96, 6.0);
    let bias = rand_bias(0x15A1, 40);
    for isa in [Isa::Scalar, Isa::Avx2Fma, Isa::Avx512f, Isa::Neon] {
        let hw = HwConfig::for_isa(isa, CacheParams::default());
        let runnable = hw.mr <= simd::active().tile.max_mr;
        assert!(runnable, "matrix rows must fit the universal max_mr");
        assert_bcrc_parity(
            &enc,
            GemmParams::default(),
            hw,
            PackOverrides::default(),
            13,
            Some(&bias),
            0x33CC + isa.to_u8() as u64,
            &format!("isa={}", isa.name()),
        );
    }
}

/// A pack_mr above the tile's max_mr must take the in-process axpy
/// fallback (same guard the `GRIM_FORCE_AXPY=1` env leg forces) and
/// stay bit-identical.
#[test]
fn oversized_mr_takes_axpy_fallback() {
    let enc = random_enc(0x0E51, 48, 96, 5.0);
    let bias = rand_bias(0x0E52, 48);
    let hw = HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default());
    let ov = PackOverrides { kc: 0, mc: 0, mr: 16 };
    let p = pack_bcrc(&enc, GemmParams::default(), 13, hw, ov);
    assert!(
        p.shape.mr > simd::active().tile.max_mr,
        "fixture must exceed the register-tile height"
    );
    assert_bcrc_parity(&enc, GemmParams::default(), hw, ov, 13, Some(&bias), 0x44DD, "mr=16");
}

/// lre=false and gemv-shaped layers pack to mr=1 row-major layouts;
/// both must stay bit-identical (n=1 never enters the tile path, n>1
/// runs height-1 panels).
#[test]
fn mr1_and_gemv_layouts_parity() {
    let enc = random_enc(0x6E3F, 32, 64, 4.0);
    let bias = rand_bias(0x6E30, 32);
    let hw = HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default());
    // lre=false: mr=1 interleave, n>1.
    assert_bcrc_parity(
        &enc,
        GemmParams { lre: false, ..GemmParams::default() },
        hw,
        PackOverrides::default(),
        9,
        Some(&bias),
        0x55EE,
        "lre=false",
    );
    // gemv: row-major packing, n=1.
    assert_bcrc_parity(
        &enc,
        GemmParams::default(),
        hw,
        PackOverrides::default(),
        1,
        Some(&bias),
        0x55EF,
        "gemv",
    );
}

/// The parallel packed path (static LPT schedule over the same layout)
/// agrees with the serial regtile path bit-for-bit at several bucket
/// counts.
#[test]
fn parallel_regtile_matches_serial() {
    let enc = random_enc(0x9A10, 56, 96, 5.0);
    let params = GemmParams::default();
    let hw = HwConfig::for_isa(Isa::Avx512f, CacheParams::default());
    let p = Arc::new(pack_bcrc(&enc, params, 16, hw, PackOverrides::default()));
    let gemm = BcrcGemm::new(enc.clone(), params).with_packed(Arc::clone(&p));
    let bias = rand_bias(0x9A11, enc.rows);
    let x = rand_x(0x9A12, enc.cols, 16);
    let mut serial = vec![0.0f32; enc.rows * 16];
    let mut gather = vec![0.0f32; enc.max_group_cols()];
    gemm.execute_into_ep(
        x.data(),
        16,
        &mut serial,
        &mut gather,
        simd::active(),
        Epilogue::BiasRelu(&bias),
    );
    for threads in [1usize, 2, 5] {
        let pool = ThreadPool::new(threads);
        let part = Arc::new(p.lpt_partition(threads));
        let mut par = vec![0.0f32; enc.rows * 16];
        gemm.execute_parallel_into_ep(
            x.data(),
            16,
            &mut par,
            Some(&part),
            &pool,
            simd::active(),
            Epilogue::BiasRelu(&bias),
        );
        assert_eq!(serial, par, "threads={threads}: parallel != serial");
    }
}

fn quantize_input(x: &Tensor) -> (Vec<u8>, quant::QParams) {
    let (lo, hi) = quant::minmax(x.data());
    let qx = quant::choose_qparams(lo, hi);
    let mut xq = vec![0u8; x.data().len()];
    quant::quantize_activations(x.data(), qx, &mut xq);
    (xq, qx)
}

/// i8 packed execution must be **bit-identical** between the scalar and
/// dispatched kernel tables — not merely close. Every i8 path
/// accumulates in i32 (exact, order-independent) and funnels through
/// the single `quant::requantize`, so the f32 outputs can be compared
/// with `assert_eq!`. Covers n>1 panel spans, the n=1 row-major gemv,
/// and all three epilogue flavors.
#[test]
fn i8_scalar_vs_simd_exact_parity() {
    let enc = random_enc(0x18A0, 40, 96, 5.0);
    let params = GemmParams::default();
    let hw = HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default());
    let bias = rand_bias(0x18A1, enc.rows);
    for n in [1usize, 5, 16, 17] {
        let p = Arc::new(pack_bcrc(&enc, params, n, hw, PackOverrides::default()).quantize_i8());
        p.validate_against(&enc).unwrap();
        let gemm = BcrcGemm::new(enc.clone(), params).with_packed(Arc::clone(&p));
        let x = rand_x(0x18A2 + n as u64, enc.cols, n);
        let (xq, qx) = quantize_input(&x);
        for ep in [Epilogue::None, Epilogue::BiasRelu(&bias), Epilogue::Relu6] {
            let mut a = vec![0.0f32; enc.rows * n];
            let mut b = vec![0.0f32; enc.rows * n];
            let mut gather = vec![0u8; p.max_width.max(1)];
            gemm.execute_i8_into_ep(&xq, n, &mut a, &mut gather, qx, simd::active(), ep);
            gemm.execute_i8_into_ep(&xq, n, &mut b, &mut gather, qx, simd::scalar(), ep);
            assert_eq!(a, b, "n={n} ep={ep:?}: i8 dispatched != scalar");
        }
    }
}

/// The parallel i8 path (static LPT schedule) is bit-identical to the
/// serial i8 path at several bucket counts, for both the panel (n>1)
/// and gemv (n=1) shapes.
#[test]
fn i8_parallel_matches_serial() {
    let enc = random_enc(0x18B0, 56, 96, 5.0);
    let params = GemmParams::default();
    let hw = HwConfig::for_isa(Isa::Avx2Fma, CacheParams::default());
    let bias = rand_bias(0x18B1, enc.rows);
    for n in [1usize, 16] {
        let p = Arc::new(pack_bcrc(&enc, params, n, hw, PackOverrides::default()).quantize_i8());
        let gemm = BcrcGemm::new(enc.clone(), params).with_packed(Arc::clone(&p));
        let x = rand_x(0x18B2 + n as u64, enc.cols, n);
        let (xq, qx) = quantize_input(&x);
        let mut serial = vec![0.0f32; enc.rows * n];
        let mut gather = vec![0u8; p.max_width.max(1)];
        gemm.execute_i8_into_ep(
            &xq,
            n,
            &mut serial,
            &mut gather,
            qx,
            simd::active(),
            Epilogue::BiasRelu(&bias),
        );
        for threads in [1usize, 2, 5] {
            let pool = ThreadPool::new(threads);
            let part = Arc::new(p.lpt_partition(threads));
            let mut par = vec![0.0f32; enc.rows * n];
            gemm.execute_i8_parallel_into_ep(
                &xq,
                n,
                &mut par,
                &part,
                &pool,
                qx,
                simd::active(),
                Epilogue::BiasRelu(&bias),
            );
            assert_eq!(serial, par, "n={n} threads={threads}: i8 parallel != serial");
        }
    }
}

/// Packed-dense regtile panels (contiguous column tiles) are bit-
/// identical to the strided tiled kernel across mr clamps, degenerate
/// kc, and n tails — serial path, both kernel tables.
#[test]
fn dense_packed_regtile_parity() {
    let mut rng = Rng::new(0xD3A5);
    for (m, k) in [(5usize, 16usize), (24, 48), (31, 96)] {
        let w = Tensor::rand_uniform(&[m, k], 1.0, &mut rng);
        let bias = rand_bias(0xD3A6, m);
        for mr in [1usize, 2, 4] {
            for kc in [1usize, 7, 256] {
                let p = TileParams { mr, kc, nc: 32 };
                let pd = PackedDense::pack(&w, p);
                for n in [2usize, 8, 17] {
                    let x = rand_x(0xD3A7 + n as u64, k, n);
                    for mk in [simd::active(), simd::scalar()] {
                        for ep in [Epilogue::None, Epilogue::BiasRelu(&bias)] {
                            let mut a = vec![0.0f32; m * n];
                            let mut b = vec![0.0f32; m * n];
                            tiled_gemm_packed_into_ep(&pd, x.data(), n, p, &mut a, mk, ep);
                            tiled_gemm_into_ep(&w, x.data(), n, p, &mut b, mk, ep);
                            assert_eq!(
                                a, b,
                                "dense m={m} k={k} mr={mr} kc={kc} n={n} [{}]: packed != strided",
                                mk.name
                            );
                        }
                    }
                }
            }
        }
    }
}
