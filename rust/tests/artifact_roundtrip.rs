//! `.grimc` artifact acceptance tests:
//!
//! * loading an artifact produces **bit-identical** inference outputs to
//!   the in-memory compile path on all four model presets (CI re-runs
//!   this file under `GRIM_FORCE_UNPACKED=1` and `GRIM_FORCE_SCALAR=1`);
//! * robustness: truncated files, flipped bytes (checksum), version
//!   skew, bad magic, and misaligned value sections are all rejected;
//! * a registry of artifact-loaded models serves ≥ 2 models concurrently
//!   with isolated per-model pools.

use grim::artifact;
use grim::compiler::passes::{compile, CompileOptions};
use grim::compiler::plan::ExecutionPlan;
use grim::coordinator::{Server, ServerConfig};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::serving::ModelRegistry;
use grim::tensor::Tensor;
use grim::util::Rng;
use std::sync::Arc;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn compiled(kind: ModelKind, seed: u64) -> ExecutionPlan {
    let o = InitOptions { rate: 6.0, block: [4, 16], seed };
    let m = build_model(kind, Preset::CifarMini, o);
    let w = random_weights(&m, o);
    compile(&m, &w, CompileOptions::default()).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Round-trip through bytes: the loaded plan must run bit-identically to
/// the in-memory plan on every preset (CONV, residual, depthwise, FC,
/// GRU-gate GEMV, packed and — under GRIM_FORCE_UNPACKED — unpacked).
#[test]
fn loaded_artifacts_bit_identical_on_presets() {
    for (i, kind) in KINDS.iter().enumerate() {
        let plan = compiled(*kind, 700 + i as u64);
        let bytes = artifact::to_bytes(&plan).unwrap();
        let loaded = artifact::from_bytes(&bytes).unwrap();
        assert_eq!(loaded.name, plan.name, "{kind:?}");
        assert_eq!(loaded.steps.len(), plan.steps.len(), "{kind:?}");
        assert_eq!(loaded.storage_bytes(), plan.storage_bytes(), "{kind:?}");
        assert_eq!(loaded.memory.arena_len, plan.memory.arena_len, "{kind:?}");
        assert_eq!(loaded.describe(), plan.describe(), "{kind:?}");
        let mem = Engine::new(plan, 2);
        let aot = Engine::new(loaded, 2);
        let mut rng = Rng::new(0x6A00 + i as u64);
        for case in 0..3 {
            let x = input_for(&mem, &mut rng);
            let a = mem.run(&x).unwrap();
            let b = aot.run(&x).unwrap();
            assert_eq!(a, b, "{kind:?} case {case}: artifact output must be bit-identical");
        }
    }
}

/// The artifact also round-trips through the filesystem, and the loaded
/// engine adapts its partitions to a different pool size while staying
/// bit-identical.
#[test]
fn file_round_trip_and_pool_adaptation() {
    let plan = compiled(ModelKind::Vgg16, 710);
    let tmp = std::env::temp_dir().join("grim_test_roundtrip.grimc");
    artifact::save_grimc(&tmp, &plan).unwrap();
    let loaded = artifact::load_grimc(&tmp).unwrap();
    std::fs::remove_file(&tmp).ok();
    let mem = Engine::new(plan, 2);
    // 3 workers ≠ the compile-time 8 buckets: Engine::new rebalances.
    let aot = Engine::new(loaded, 3);
    let mut rng = Rng::new(0x6B00);
    let x = input_for(&mem, &mut rng);
    assert_eq!(mem.run(&x).unwrap(), aot.run(&x).unwrap());
}

/// Legacy artifacts still load on the current runtime: v1 (work
/// partitions embedded inside the packed structures) gets its
/// partitions hoisted into a synthesized `ScheduleSet`; v2 (no
/// hardware-matrix stats, no mixed-width grammar) reads with default
/// stats; v3 (no cost table) gets its cost model recomputed at load.
/// All are bit-identical to the current-version round-trip and to the
/// in-memory plan — at the compile-time bucket count *and* after a
/// pool-size rebalance.
#[test]
fn old_version_artifacts_still_load_bit_identically() {
    for (i, kind) in [ModelKind::Vgg16, ModelKind::Gru].iter().enumerate() {
        let plan = compiled(*kind, 740 + i as u64);
        let v1 = artifact::to_bytes_versioned(&plan, 1).unwrap();
        assert_eq!(u32::from_le_bytes(v1[4..8].try_into().unwrap()), 1, "v1 header version");
        let v2 = artifact::to_bytes_versioned(&plan, 2).unwrap();
        assert_eq!(u32::from_le_bytes(v2[4..8].try_into().unwrap()), 2, "v2 header version");
        let v3 = artifact::to_bytes_versioned(&plan, 3).unwrap();
        assert_eq!(u32::from_le_bytes(v3[4..8].try_into().unwrap()), 3, "v3 header version");
        let v4 = artifact::to_bytes(&plan).unwrap();
        assert_eq!(
            u32::from_le_bytes(v4[4..8].try_into().unwrap()),
            artifact::GRIMC_VERSION,
            "current header version"
        );
        let from_v1 = artifact::from_bytes(&v1).unwrap();
        let from_v2 = artifact::from_bytes(&v2).unwrap();
        let from_v3 = artifact::from_bytes(&v3).unwrap();
        let from_v4 = artifact::from_bytes(&v4).unwrap();
        if plan.packing.enabled {
            assert!(
                !from_v1.schedules.is_empty(),
                "{kind:?}: v1 load must synthesize a schedule set"
            );
        }
        // Pre-v3 files carry no hardware-matrix stats; the current
        // version round-trips them exactly.
        assert_eq!(from_v2.packing.hw_mr, 0, "{kind:?}: v2 stats must default");
        assert_eq!(from_v4.packing.isa, plan.packing.isa, "{kind:?}: v4 must keep the ISA row");
        assert_eq!(from_v4.packing.hw_mr, plan.packing.hw_mr, "{kind:?}");
        assert_eq!(from_v4.packing.mixed_layers, plan.packing.mixed_layers, "{kind:?}");
        assert_eq!(from_v4.packing.wide_groups, plan.packing.wide_groups, "{kind:?}");
        // Every load path ends with the full cost table: v4 stores and
        // validates it, pre-v4 recomputes it — all bit-equal to the
        // compile-time pass.
        for (tag, loaded) in
            [("v1", &from_v1), ("v2", &from_v2), ("v3", &from_v3), ("v4", &from_v4)]
        {
            assert_eq!(loaded.costs.len(), plan.steps.len(), "{kind:?}: {tag} cost table size");
            assert_eq!(loaded.costs, plan.costs, "{kind:?}: {tag} cost table differs");
        }
        let mem = Engine::new(plan, 2);
        let e1 = Engine::new(from_v1, 2);
        let e2 = Engine::new(from_v2, 3); // different pool: rebalance leg
        let e3 = Engine::new(from_v3, 2);
        let e4 = Engine::new(from_v4, 2);
        let mut rng = Rng::new(0x6C00 + i as u64);
        for case in 0..2 {
            let x = input_for(&mem, &mut rng);
            let a = mem.run(&x).unwrap();
            assert_eq!(a, e1.run(&x).unwrap(), "{kind:?} case {case}: v1 artifact differs");
            assert_eq!(a, e2.run(&x).unwrap(), "{kind:?} case {case}: v2 artifact differs");
            assert_eq!(a, e3.run(&x).unwrap(), "{kind:?} case {case}: v3 artifact differs");
            assert_eq!(a, e4.run(&x).unwrap(), "{kind:?} case {case}: v4 artifact differs");
        }
    }
}

fn sample_bytes() -> Vec<u8> {
    artifact::to_bytes(&compiled(ModelKind::Gru, 720)).unwrap()
}

#[test]
fn rejects_truncated() {
    let bytes = sample_bytes();
    for keep in [0usize, 8, 27, bytes.len() / 10, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            artifact::from_bytes(&bytes[..keep]).is_err(),
            "truncation to {keep}/{} bytes must be rejected",
            bytes.len()
        );
    }
}

#[test]
fn rejects_corrupted_checksum() {
    let mut bytes = sample_bytes();
    // Flip one byte deep in the payload (value sections live at the end).
    let at = bytes.len() - 9;
    bytes[at] ^= 0x40;
    let err = artifact::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");
}

#[test]
fn rejects_version_skew() {
    let mut bytes = sample_bytes();
    bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
    let err = artifact::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn rejects_bad_magic() {
    let mut bytes = sample_bytes();
    bytes[0..4].copy_from_slice(b"NOPE");
    let err = artifact::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");
}

#[test]
fn rejects_misaligned_section() {
    let mut bytes = sample_bytes();
    let n_sections = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    assert!(n_sections > 0, "fixture must carry value sections");
    // Nudge the first section off its 64-byte boundary, then re-seal the
    // checksum so only the alignment check can object.
    let off = u64::from_le_bytes(bytes[28..36].try_into().unwrap());
    bytes[28..36].copy_from_slice(&(off + 4).to_le_bytes());
    let ck = artifact::fnv1a64(&bytes[16..]);
    bytes[8..16].copy_from_slice(&ck.to_le_bytes());
    let err = artifact::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("misaligned"), "{err}");
}

#[test]
fn rejects_meta_garbage_with_valid_checksum() {
    let mut bytes = sample_bytes();
    let n_sections = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    // Corrupt the first meta byte (the model-name length) and re-seal:
    // structural validation, not the checksum, must catch it.
    let meta_off = 28 + 16 * n_sections;
    bytes[meta_off] = 0xFF;
    bytes[meta_off + 1] = 0xFF;
    bytes[meta_off + 2] = 0xFF;
    bytes[meta_off + 3] = 0xFF;
    let ck = artifact::fnv1a64(&bytes[16..]);
    bytes[8..16].copy_from_slice(&ck.to_le_bytes());
    assert!(artifact::from_bytes(&bytes).is_err());
}

/// Two artifact-loaded models served concurrently through one registry
/// server: isolated pools, correct routing, eviction budget honored.
#[test]
fn registry_serves_two_artifact_models() {
    let dir = std::env::temp_dir().join("grim_test_registry_models");
    std::fs::create_dir_all(&dir).unwrap();
    artifact::save_grimc(&dir.join("cnn.grimc"), &compiled(ModelKind::MobilenetV2, 730)).unwrap();
    artifact::save_grimc(&dir.join("rnn.grimc"), &compiled(ModelKind::Gru, 731)).unwrap();

    let registry = Arc::new(ModelRegistry::new(2));
    let names = registry.load_dir(&dir).unwrap();
    assert_eq!(names, vec!["cnn".to_string(), "rnn".to_string()]);
    let server = Arc::new(Server::start_registry(Arc::clone(&registry), ServerConfig::default()));
    let mut handles = Vec::new();
    for (t, name) in [(0u64, "cnn"), (1, "rnn"), (2, "cnn"), (3, "rnn")] {
        let s = Arc::clone(&server);
        let reg = Arc::clone(&registry);
        handles.push(std::thread::spawn(move || {
            let engine = reg.get(name).unwrap();
            let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
            let mut rng = Rng::new(400 + t);
            for _ in 0..4 {
                let x = Tensor::rand_uniform(&dims, 1.0, &mut rng);
                let resp = s.infer_on(name, x).unwrap();
                assert!(resp.output.data().iter().all(|v| v.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(server.stats().completed, 16);
    for ms in registry.stats() {
        assert_eq!(ms.pool.checkouts, 8, "model '{}' pool must count only its own runs", ms.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}
