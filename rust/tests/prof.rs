//! Cost-model + roofline-profile acceptance tests:
//!
//! * the compiler's cost pass covers every step of every preset and its
//!   counters obey the model's invariants (sparse-effective flops never
//!   exceed dense-equivalent, intensity is exactly flops/bytes, totals
//!   are field sums);
//! * a measured run joins against the cost table into a per-layer
//!   profile whose report validates against the `grim_bench_schema`
//!   shape and self-diffs clean (the `grim bench-diff` identity).
//!
//! The flop/byte conventions themselves are cross-validated by an
//! independent pure-Python enumeration in `python/tests/sim_prof.py`.

use grim::compiler::cost;
use grim::compiler::passes::{compile, CompileOptions};
use grim::compiler::plan::ExecutionPlan;
use grim::engine::Engine;
use grim::gemm::Isa;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::obs::prof;
use grim::tensor::Tensor;
use grim::util::Rng;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn compiled(kind: ModelKind, seed: u64) -> ExecutionPlan {
    let o = InitOptions { rate: 6.0, block: [4, 16], seed };
    let m = build_model(kind, Preset::CifarMini, o);
    let w = random_weights(&m, o);
    compile(&m, &w, CompileOptions::default()).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// The cost table is total (one entry per step) and each entry obeys
/// the model's invariants on every preset: sparse-effective flops never
/// exceed the dense-equivalent count, stored nnz never exceeds the
/// dense element count implied by the flop ratio, and the recorded
/// intensity is exactly `flops / (weight_bytes + act_bytes)`.
#[test]
fn cost_tables_cover_presets_with_sparse_leq_dense() {
    for (i, kind) in KINDS.iter().enumerate() {
        let plan = compiled(*kind, 900 + i as u64);
        assert_eq!(plan.costs.len(), plan.steps.len(), "{kind:?}: one cost per step");
        let mut any_flops = false;
        for (si, c) in plan.costs.iter().enumerate() {
            assert!(
                c.flops <= c.dense_flops,
                "{kind:?} step {si}: sparse flops {} > dense {}",
                c.flops,
                c.dense_flops
            );
            let bytes = c.weight_bytes + c.act_bytes;
            let want = if bytes == 0 { 0.0 } else { c.flops as f64 / bytes as f64 };
            assert_eq!(
                c.arithmetic_intensity, want,
                "{kind:?} step {si}: intensity must be exactly flops/bytes"
            );
            any_flops |= c.flops > 0;
        }
        assert!(any_flops, "{kind:?}: a compiled model must cost > 0 flops");
        // Sparsified GEMM layers exist in every preset at rate 6.0, so
        // the whole-plan dense-equivalent total must strictly exceed
        // the sparse-effective total.
        let t = cost::total(&plan.costs);
        assert!(t.dense_flops > t.flops, "{kind:?}: no plan-level sparsity win");
    }
}

/// Plan totals are exact field sums of the per-step table.
#[test]
fn totals_are_field_sums() {
    let plan = compiled(ModelKind::Resnet18, 910);
    let t = cost::total(&plan.costs);
    let sum = |f: fn(&cost::LayerCost) -> u64| plan.costs.iter().map(f).sum::<u64>();
    assert_eq!(t.flops, sum(|c| c.flops));
    assert_eq!(t.dense_flops, sum(|c| c.dense_flops));
    assert_eq!(t.weight_bytes, sum(|c| c.weight_bytes));
    assert_eq!(t.act_bytes, sum(|c| c.act_bytes));
    assert_eq!(t.nnz, sum(|c| c.nnz));
}

/// Joining a measured run with the cost table yields one profile row
/// per step, classifies every layer under exactly one roof, and emits a
/// report that passes schema validation and self-diffs with zero
/// regressions at any threshold.
#[test]
fn profile_joins_measure_and_validates_schema() {
    for (i, kind) in KINDS.iter().enumerate() {
        let plan = compiled(*kind, 920 + i as u64);
        let mut engine = Engine::new(plan, 2);
        engine.collect_metrics = true;
        let mut rng = Rng::new(0x9F00 + i as u64);
        let x = input_for(&engine, &mut rng);
        let (_, m) = engine.run_with_metrics(&x).unwrap();
        // A pinned machine model keeps the assertions host-independent.
        let machine = prof::MachineModel::for_isa(Isa::Scalar, 2);
        let p = prof::join(&engine.plan().costs, &m, &machine).unwrap();
        assert_eq!(p.layers.len(), engine.plan().steps.len(), "{kind:?}");
        for l in &p.layers {
            assert!(l.wall_us >= 0.0 && l.busy_us >= 0.0, "{kind:?}");
            assert!(l.sparsity_win() >= 1.0, "{kind:?} node {}: win < 1", l.node);
            let expect_mem = l.cost.arithmetic_intensity < machine.ridge();
            assert_eq!(l.bound == prof::Bound::Memory, expect_mem, "{kind:?} node {}", l.node);
            assert!(l.roof_gflops <= machine.peak_gflops + 1e-9, "{kind:?}");
        }
        assert_eq!(p.total.cost.flops, cost::total(&engine.plan().costs).flops, "{kind:?}");
        let report = prof::profile_report(&format!("{kind:?}"), &p, &machine);
        let obj = report.to_json_with(&machine);
        prof::validate_report(&obj).unwrap();
        // bench-diff identity: a report compared against itself is
        // regression-free even at threshold 0.
        let d = prof::diff_reports(&obj, &obj, 0.0).unwrap();
        assert!(d.regressions.is_empty(), "{kind:?}: self-diff regressed");
        assert!(d.compared > 0, "{kind:?}: self-diff compared nothing");
    }
}

/// Joining refuses a run whose metrics were not collected (length
/// mismatch) instead of silently misattributing.
#[test]
fn join_rejects_mismatched_metrics() {
    let plan = compiled(ModelKind::Gru, 930);
    let costs = plan.costs.clone();
    let machine = prof::MachineModel::for_isa(Isa::Scalar, 2);
    let empty = grim::engine::RunMetrics::default();
    let err = prof::join(&costs, &empty, &machine).unwrap_err();
    assert!(err.to_string().contains("metrics collection off"), "{err}");
}
