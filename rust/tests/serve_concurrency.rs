//! Concurrent-dispatch acceptance tests (the PR 8 serving tentpole):
//!
//! * two resident models' batches demonstrably **overlap in time** on
//!   different dispatcher lanes (trace-span evidence),
//! * forcing serial dispatch (`max_inflight = 1`) puts every batch on
//!   one lane thread,
//! * no request is lost under concurrent dispatch racing LRU eviction,
//! * a request for a cold model is answered after a **background
//!   artifact load** instead of failing,
//! * deadline expiry surfaces as the typed [`ServeError::DeadlineExceeded`].
//!
//! Tracing state is process-global; tests that flip it serialize on
//! [`trace_lock`] and look only for their own interned model names.

use grim::compiler::passes::{compile, CompileOptions};
use grim::coordinator::{BatchPolicy, ServeError, Server, ServerConfig};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::obs::trace::{self, SpanKind};
use grim::serving::ModelRegistry;
use grim::tensor::Tensor;
use grim::util::Rng;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Serializes tests that flip the process-global tracing state.
fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn plan_for(kind: ModelKind, preset: Preset, seed: u64) -> grim::compiler::ExecutionPlan {
    let opts = InitOptions { rate: 4.0, block: [4, 16], seed };
    let m = build_model(kind, preset, opts);
    let w = random_weights(&m, opts);
    compile(&m, &w, CompileOptions::default()).unwrap()
}

fn gru_plan(seed: u64) -> grim::compiler::ExecutionPlan {
    plan_for(ModelKind::Gru, Preset::TimitMini, seed)
}

fn serial_forced() -> bool {
    std::env::var("GRIM_SERIAL_DISPATCH").is_ok_and(|v| v == "1")
}

fn config_with_lanes(lanes: usize) -> ServerConfig {
    ServerConfig {
        batch: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
        max_inflight: Some(lanes),
        ..ServerConfig::default()
    }
}

/// Drive `reqs` requests per client thread against `model` and assert
/// every one succeeds.
fn hammer(server: &Arc<Server>, model: &str, clients: u64, reqs: usize) -> Vec<std::thread::JoinHandle<()>> {
    (0..clients)
        .map(|t| {
            let s = Arc::clone(server);
            let name = model.to_string();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1000 * t + 7);
                for _ in 0..reqs {
                    let x = Tensor::rand_uniform(&[3, 32, 32], 1.0, &mut rng);
                    let resp = s.infer_on(&name, x).unwrap();
                    assert!(resp.error.is_none());
                }
            })
        })
        .collect()
}

/// (a) With two dispatcher lanes and two busy models, some pair of
/// dispatch spans — one per model, on different lane threads — must
/// overlap in wall time. Skipped under the serial-dispatch CI leg,
/// where one lane is the whole point.
#[test]
fn two_models_batches_overlap_across_lanes() {
    if serial_forced() {
        return;
    }
    let _g = trace_lock();
    trace::enable(1); // sample every batch
    let registry = Arc::new(ModelRegistry::new(4));
    // CNNs run for milliseconds per batch — with both models saturated
    // and two lanes, overlap is structural, not a lucky race.
    registry.insert_plan("conc-cnn-a", plan_for(ModelKind::Vgg16, Preset::CifarMini, 51));
    registry.insert_plan("conc-cnn-b", plan_for(ModelKind::Vgg16, Preset::CifarMini, 52));
    let server = Arc::new(Server::start_registry(Arc::clone(&registry), config_with_lanes(2)));
    assert_eq!(server.dispatch_lanes(), 2);

    let mut handles = hammer(&server, "conc-cnn-a", 2, 6);
    handles.extend(hammer(&server, "conc-cnn-b", 2, 6));
    for h in handles {
        h.join().unwrap();
    }
    trace::disable();

    let stats = server.stats();
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.failed, 0, "no request loss under concurrent dispatch");

    let id_a = trace::intern("conc-cnn-a");
    let id_b = trace::intern("conc-cnn-b");
    let spans = trace::snapshot();
    let dispatch =
        |id: u32| spans.iter().filter(move |s| s.kind == SpanKind::Dispatch && s.model == id);
    assert!(dispatch(id_a).count() > 0 && dispatch(id_b).count() > 0, "both models traced");
    let overlap = dispatch(id_a).any(|a| {
        dispatch(id_b).any(|b| {
            a.tid != b.tid
                && a.start_us < b.start_us + b.dur_us
                && b.start_us < a.start_us + a.dur_us
        })
    });
    assert!(
        overlap,
        "expected a model-a dispatch span and a model-b dispatch span on \
         different lanes overlapping in time"
    );

    // The new metric families exist and saw traffic.
    let metrics = server.metrics();
    let waits = metrics.histograms_named("grim_dispatch_wait_us");
    assert!(!waits.is_empty(), "dispatch_wait histograms registered");
    let total: u64 = waits.iter().map(|(_, h)| h.count()).sum();
    assert!(total > 0, "dispatch_wait recorded per batch");
    let prom = server.render_prometheus();
    assert!(prom.contains("grim_inflight_batches"), "{prom}");
    assert!(prom.contains("grim_dispatch_wait_us"), "{prom}");

    // Everything drained: once the lanes are joined by shutdown, the
    // inflight gauge must be back to zero.
    assert_eq!(stats.dispatch_lanes, 2);
    let server = Arc::try_unwrap(server).unwrap_or_else(|_| panic!("clients still hold refs"));
    server.shutdown();
    assert_eq!(metrics.gauge("grim_inflight_batches", &[]).get(), 0);
}

/// Serial dispatch (`max_inflight = 1`) is exactly the old scheduler:
/// one lane thread executes every batch, so all dispatch spans of both
/// models carry the same thread ring id.
#[test]
fn serial_dispatch_runs_on_one_lane() {
    let _g = trace_lock();
    trace::enable(1);
    let registry = Arc::new(ModelRegistry::new(2));
    registry.insert_plan("ser-rnn-a", gru_plan(61));
    registry.insert_plan("ser-rnn-b", gru_plan(62));
    let server = Arc::new(Server::start_registry(Arc::clone(&registry), config_with_lanes(1)));
    assert_eq!(server.dispatch_lanes(), 1);
    let mut rng = Rng::new(5);
    for i in 0..12 {
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let name = if i % 2 == 0 { "ser-rnn-a" } else { "ser-rnn-b" };
        server.infer_on(name, x).unwrap();
    }
    trace::disable();
    let ids = [trace::intern("ser-rnn-a"), trace::intern("ser-rnn-b")];
    let tids: std::collections::HashSet<usize> = trace::snapshot()
        .iter()
        .filter(|s| s.kind == SpanKind::Dispatch && ids.contains(&s.model))
        .map(|s| s.tid)
        .collect();
    assert_eq!(tids.len(), 1, "serial dispatch must use exactly one lane thread, saw {tids:?}");
}

/// (b) Concurrent dispatch racing LRU eviction: every submitted request
/// gets exactly one response — success or a typed error — and the
/// server neither hangs nor drops requests when a model is evicted
/// mid-traffic.
#[test]
fn no_request_loss_under_eviction() {
    // Measure one resident model, then budget the real registry so two
    // can never be resident together.
    let one_model_bytes = {
        let probe = ModelRegistry::new(1);
        probe.insert_plan("probe", gru_plan(71));
        probe.resident_bytes()
    };
    let registry = Arc::new(ModelRegistry::with_budget(4, one_model_bytes + one_model_bytes / 2));
    registry.insert_plan("ev-a", gru_plan(72));
    let server = Arc::new(Server::start_registry(Arc::clone(&registry), config_with_lanes(2)));

    let total_per_thread = 30usize;
    let counts: Vec<std::thread::JoinHandle<(u64, u64)>> = (0..4u64)
        .map(|t| {
            let s = Arc::clone(&server);
            std::thread::spawn(move || {
                let mut rng = Rng::new(400 + t);
                let (mut ok, mut failed) = (0u64, 0u64);
                for i in 0..total_per_thread {
                    let name = if (i as u64 + t) % 2 == 0 { "ev-a" } else { "ev-b" };
                    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
                    let rx = s.submit_to(name, x).unwrap();
                    // Every request MUST be answered: recv() hanging or
                    // erroring here is request loss.
                    let resp = rx.recv().expect("request dropped without a response");
                    match resp.error {
                        None => ok += 1,
                        Some(ServeError::ModelNotResident { .. }) => failed += 1,
                        Some(other) => panic!("unexpected error: {other}"),
                    }
                }
                (ok, failed)
            })
        })
        .collect();
    // Mid-traffic, load the second model; the budget evicts the first.
    std::thread::sleep(Duration::from_millis(30));
    registry.insert_plan("ev-b", gru_plan(73));
    let (mut ok, mut failed) = (0u64, 0u64);
    for h in counts {
        let (o, f) = h.join().unwrap();
        ok += o;
        failed += f;
    }
    assert_eq!(ok + failed, 4 * total_per_thread as u64, "every request answered exactly once");
    assert!(ok > 0, "some requests must succeed");
    let stats = server.stats();
    assert_eq!(stats.completed, ok);
    assert_eq!(stats.failed, failed);
    assert!(registry.evictions() >= 1, "the budget must have evicted a model");
}

/// Scratch directory for artifact tests, cleaned up on drop.
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> Self {
        let dir = std::env::temp_dir().join(format!("grim-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// (c) A request for a model that is not resident but has an artifact on
/// disk is parked, loaded in the background, re-enqueued, and answered
/// successfully — the client just sees a slower first request. A corrupt
/// artifact fails the parked request with the typed error instead.
#[test]
fn cold_model_served_via_background_load() {
    let tmp = TempDir::new("serve-cold");
    grim::artifact::save_grimc(&tmp.0.join("cold-rnn.grimc"), &gru_plan(77)).unwrap();
    std::fs::write(tmp.0.join("corrupt.grimc"), b"not an artifact").unwrap();

    let registry = Arc::new(ModelRegistry::new(2));
    registry.set_artifact_dir(&tmp.0);
    let server = Server::start_registry(Arc::clone(&registry), ServerConfig::default());
    assert!(registry.get("cold-rnn").is_none(), "cold at start");

    let mut rng = Rng::new(9);
    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
    let resp = server.infer_on("cold-rnn", x.clone()).expect("cold request must succeed");
    assert!(resp.error.is_none());
    assert!(registry.get("cold-rnn").is_some(), "model resident after background load");
    let loads_ok = server.metrics().counter("grim_background_loads_total", &[("result", "ok")]);
    assert_eq!(loads_ok.get(), 1, "exactly one background load");

    // Now warm: a second request is served without another load.
    server.infer_on("cold-rnn", x.clone()).unwrap();
    assert_eq!(loads_ok.get(), 1);

    // Corrupt artifact: the load runs, fails, and the parked request
    // comes back with the typed not-resident error (not a hang).
    let resp = server.submit_to("corrupt", x).unwrap().recv().unwrap();
    assert_eq!(resp.error, Some(ServeError::ModelNotResident { model: "corrupt".into() }));
    let loads_failed =
        server.metrics().counter("grim_background_loads_total", &[("result", "failed")]);
    assert_eq!(loads_failed.get(), 1);

    let stats = server.shutdown();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 1);
}

/// (d) Deadline expiry at dequeue: the typed error comes back, the
/// request never executes, and the expired accounting shows up in both
/// `ServerStats` and the per-model Prometheus counter.
#[test]
fn deadline_expiry_surfaces_typed_error() {
    let plan = gru_plan(81);
    let model_name = plan.name.clone();
    let server = Server::start(Engine::new(plan, 2), ServerConfig::default());
    let mut rng = Rng::new(13);
    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);

    let resp = server
        .submit_with_deadline(None, x.clone(), Duration::ZERO)
        .unwrap()
        .recv()
        .unwrap();
    assert_eq!(resp.error, Some(ServeError::DeadlineExceeded));
    assert_eq!(resp.exec_ms, 0.0, "expired requests must not execute");

    // A comfortable deadline serves normally.
    let ok = server
        .submit_with_deadline(None, x, Duration::from_secs(30))
        .unwrap()
        .recv()
        .unwrap();
    assert!(ok.error.is_none());

    let expired = server
        .metrics()
        .counter("grim_requests_expired_total", &[("model", &model_name)]);
    assert_eq!(expired.get(), 1, "per-model expired counter");
    let stats = server.shutdown();
    assert_eq!(stats.expired, 1);
    assert_eq!(stats.failed, 1, "expired is a subset of failed");
    assert_eq!(stats.completed, 1);
}
