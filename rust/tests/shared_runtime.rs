//! Shared execution-runtime acceptance tests (the one-scheduler serving
//! tier):
//!
//! * N registry models share exactly **one** worker pool — the process
//!   spawns precisely `runtime.threads()` workers no matter how many
//!   models are resident or how much traffic they serve concurrently;
//! * partition rebalancing (pool-size adaptation and quota changes) is a
//!   **pure-metadata** operation: packed value buffers keep their
//!   pointer identity even when the plan's kernel `Arc`s are shared (the
//!   old `Arc::make_mut` deep-copy path is gone), the pack-invocation
//!   counter stays flat, and results stay bit-identical to `run_naive`;
//! * LRU eviction under in-flight load never breaks a held engine;
//! * unpacked plans (`GRIM_FORCE_UNPACKED=1` CI leg) carry no schedules
//!   and rebalance as a no-op.

use grim::compiler::passes::{compile, CompileOptions};
use grim::compiler::plan::{ExecutionPlan, KernelImpl};
use grim::coordinator::{Server, ServerConfig};
use grim::engine::Engine;
use grim::exec::Runtime;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::serving::{plan_resident_bytes, ModelRegistry};
use grim::sparse::packed::pack_invocations;
use grim::tensor::Tensor;
use grim::util::threadpool::{workers_live, workers_spawned};
use grim::util::Rng;
use std::sync::{Arc, Mutex};

/// The worker counters are process-global and tests in this file run
/// concurrently, so every test that creates pools or reads the counters
/// serializes on this lock.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn plan_for(kind: ModelKind, preset: Preset, seed: u64) -> ExecutionPlan {
    let o = InitOptions { rate: 6.0, block: [4, 16], seed };
    let m = build_model(kind, preset, o);
    let w = random_weights(&m, o);
    compile(&m, &w, CompileOptions::default()).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Pointer identity of every packed BCRC value buffer (and the packed
/// `Arc`s themselves) in a plan — the zero-copy witness.
fn packed_ptrs(plan: &ExecutionPlan) -> Vec<(*const grim::sparse::PackedBcrc, *const f32)> {
    let mut v = Vec::new();
    grim::compiler::plan::for_each_kernel(&plan.steps, |k| {
        if let KernelImpl::Bcrc { gemm } = k {
            if let Some(p) = &gemm.packed {
                v.push((Arc::as_ptr(p), p.values.as_slice().as_ptr()));
            }
        }
    });
    v
}

/// Tentpole invariant: two resident models, one shared runtime, exactly
/// `threads` worker threads alive — including under concurrent traffic
/// to both models.
#[test]
fn registry_models_share_exactly_one_pool() {
    let _g = lock();
    let live_before = workers_live();
    let spawned_before = workers_spawned();
    {
        let runtime = Runtime::new(4);
        assert_eq!(workers_spawned() - spawned_before, 4, "runtime spawns its workers once");
        let registry = Arc::new(ModelRegistry::with_runtime(Arc::clone(&runtime), usize::MAX));
        registry.insert_plan("cnn", plan_for(ModelKind::Vgg16, Preset::CifarMini, 11));
        registry.insert_plan("rnn", plan_for(ModelKind::Gru, Preset::TimitMini, 12));
        let cnn = registry.get("cnn").unwrap();
        let rnn = registry.get("rnn").unwrap();
        assert!(
            Arc::ptr_eq(&cnn.runtime(), &runtime) && Arc::ptr_eq(&rnn.runtime(), &runtime),
            "both engines must borrow the registry's runtime"
        );
        assert_eq!(
            workers_spawned() - spawned_before,
            4,
            "inserting models must spawn no additional worker threads"
        );
        assert_eq!(workers_live() - live_before, 4, "total live workers == runtime size");

        // Concurrent submits to both models through one server.
        let server =
            Arc::new(Server::start_registry(Arc::clone(&registry), ServerConfig::default()));
        let mut handles = Vec::new();
        for t in 0..2u64 {
            for name in ["cnn", "rnn"] {
                let s = Arc::clone(&server);
                let reg = Arc::clone(&registry);
                handles.push(std::thread::spawn(move || {
                    let engine = reg.get(name).unwrap();
                    let mut rng = Rng::new(500 + t);
                    for _ in 0..4 {
                        let x = input_for(&engine, &mut rng);
                        let resp = s.infer_on(name, x).unwrap();
                        assert!(resp.output.data().iter().all(|v| v.is_finite()));
                    }
                }));
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().completed, 16);
        assert_eq!(
            workers_spawned() - spawned_before,
            4,
            "serving 16 requests across 2 models spawned no extra workers"
        );
        assert_eq!(workers_live() - live_before, 4);
    }
}

/// Rebalancing a *shared* plan (cloned `Arc`s — the case that used to
/// deep-copy packed buffers via `Arc::make_mut`) to two different pool
/// sizes keeps every packed value buffer at its original address, packs
/// nothing, and stays bit-identical to `run_naive`.
#[test]
fn rebalance_performs_zero_packed_buffer_copies() {
    let _g = lock();
    let plan = plan_for(ModelKind::Vgg16, Preset::CifarMini, 21);
    let before = packed_ptrs(&plan);
    if plan.packing.enabled {
        assert!(!before.is_empty(), "fixture must carry packed BCRC layers");
    }
    let packs_before = pack_invocations();
    // plan.clone() shares every kernel Arc with `plan` — engines at 3
    // and 8 buckets then rebalance over genuinely shared packed data.
    let e3 = Engine::new(plan.clone(), 3);
    let e8 = Engine::new(plan.clone(), 8);
    assert_eq!(pack_invocations(), packs_before, "rebalance must never re-pack");
    assert_eq!(
        packed_ptrs(e3.plan()),
        before,
        "3-bucket rebalance must keep packed value Arc pointer identity"
    );
    assert_eq!(
        packed_ptrs(e8.plan()),
        before,
        "8-bucket rebalance must keep packed value Arc pointer identity"
    );
    if plan.packing.enabled {
        let s3 = e3.schedules();
        assert_eq!(s3.threads, 3);
        assert!(s3.parts.iter().all(|p| p.num_buckets() == 3));
    }
    let mut rng = Rng::new(0xC0DE);
    for _ in 0..2 {
        let x = input_for(&e3, &mut rng);
        let a = e3.run(&x).unwrap();
        assert_eq!(a, e8.run(&x).unwrap(), "bucket count must not change results");
        assert_eq!(a, e3.run_naive(&x).unwrap(), "rebalanced engine must match run_naive");
    }
}

/// Quota changes on a live registry model rebalance pure metadata:
/// pointer identity holds, outputs stay bit-identical, and the engine's
/// schedule width follows the quota.
#[test]
fn quota_change_is_pure_metadata_and_bit_identical() {
    let _g = lock();
    let registry = ModelRegistry::new(4);
    let engine = registry.insert_plan("m", plan_for(ModelKind::Vgg16, Preset::CifarMini, 31));
    let before = packed_ptrs(engine.plan());
    let mut rng = Rng::new(0xF00D);
    let x = input_for(&engine, &mut rng);
    let base = engine.run(&x).unwrap();
    let naive = engine.run_naive(&x).unwrap();
    assert_eq!(base, naive);

    let packs_before = pack_invocations();
    assert_eq!(registry.set_quota("m", 2), 2);
    assert_eq!(engine.schedules().threads, 2, "quota applies to the resident engine");
    assert_eq!(pack_invocations(), packs_before, "quota rebalance must never re-pack");
    assert_eq!(packed_ptrs(engine.plan()), before, "quota rebalance must not copy buffers");
    assert_eq!(engine.run(&x).unwrap(), base, "quota must not change results");

    registry.clear_quota("m");
    assert_eq!(engine.schedules().threads, 4);
    assert_eq!(engine.run(&x).unwrap(), base);
}

/// LRU eviction while the evicted model has traffic in flight: the held
/// engine handle keeps serving to completion (its memory is freed when
/// the last handle drops), and the registry stays consistent.
#[test]
fn lru_eviction_under_inflight_load() {
    let _g = lock();
    let a = plan_for(ModelKind::Gru, Preset::TimitMini, 41);
    let one = plan_resident_bytes(&a);
    // Room for two of these models, not three.
    let registry = Arc::new(ModelRegistry::with_budget(2, 2 * one + one / 2));
    let victim = registry.insert_plan("a", a);
    registry.insert_plan("b", plan_for(ModelKind::Gru, Preset::TimitMini, 42));
    // Touch "b" last so "a" is the LRU victim while we hold its handle.
    registry.get("a").unwrap();
    registry.get("b").unwrap();

    let worker = {
        let victim = Arc::clone(&victim);
        std::thread::spawn(move || {
            let mut rng = Rng::new(0xE71);
            for _ in 0..10 {
                let x = input_for(&victim, &mut rng);
                victim.run(&x).expect("in-flight handle must keep serving");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        })
    };
    // Evict "a" mid-traffic by inserting a third model over budget.
    registry.insert_plan("c", plan_for(ModelKind::Gru, Preset::TimitMini, 43));
    assert!(registry.get("a").is_none(), "LRU victim must be evicted");
    assert!(registry.get("b").is_some() && registry.get("c").is_some());
    assert_eq!(registry.evictions(), 1);
    worker.join().unwrap();
    // The evicted model's traffic now counts as not-resident misses.
    registry.note_miss("a");
    assert_eq!(registry.not_resident("a"), 1);
}

/// Unpacked plans (the `GRIM_FORCE_UNPACKED=1` CI leg compiles this way
/// unconditionally) carry no schedules; rebalancing is a no-op and the
/// even-split fallback stays bit-identical to `run_naive`.
#[test]
fn unpacked_plans_rebalance_as_noop() {
    let _g = lock();
    let o = InitOptions { rate: 6.0, block: [4, 16], seed: 51 };
    let m = build_model(ModelKind::Resnet18, Preset::CifarMini, o);
    let w = random_weights(&m, o);
    let plan = compile(&m, &w, CompileOptions::default().without_packing()).unwrap();
    assert!(plan.schedules.is_empty(), "unpacked plans carry no schedules");
    let engine = Engine::new(plan, 3);
    assert_eq!(engine.rebalance(5), 0, "nothing to rebuild");
    let mut rng = Rng::new(0xAB);
    let x = input_for(&engine, &mut rng);
    assert_eq!(engine.run(&x).unwrap(), engine.run_naive(&x).unwrap());
}
