//! Memory-planner correctness: the planned (arena) executor must be
//! bit-identical to the naive (owned-tensor) interpreter on every model
//! preset and backend, the packed arena must honor the no-overlap
//! invariant, and steady-state serving must stay zero-alloc (exactly one
//! arena checkout per run, arenas reused).

use grim::compiler::passes::{compile, Backend, CompileOptions};
use grim::engine::Engine;
use grim::memory::Workspace;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::tensor::Tensor;
use grim::util::Rng;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn opts(rate: f64, seed: u64) -> InitOptions {
    InitOptions { rate, block: [4, 16], seed }
}

fn engine_for(kind: ModelKind, backend: Backend, o: InitOptions, threads: usize) -> Engine {
    let module = build_model(kind, Preset::CifarMini, o);
    let weights = random_weights(&module, o);
    let plan = compile(&module, &weights, CompileOptions::for_backend(backend)).unwrap();
    Engine::new(plan, threads)
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Property: across all four presets and several random inputs, planned
/// execution produces exactly (bit-for-bit) the naive interpreter's
/// output — both paths share every kernel, so any divergence is a planner
/// bug (aliasing, stale scratch, wrong offsets).
#[test]
fn prop_planned_bit_identical_to_naive() {
    for (i, kind) in KINDS.iter().enumerate() {
        let engine = engine_for(*kind, Backend::Grim, opts(6.0, 100 + i as u64), 2);
        let mut rng = Rng::new(0x6A00 + i as u64);
        for case in 0..5 {
            let x = input_for(&engine, &mut rng);
            let planned = engine.run(&x).unwrap();
            let naive = engine.run_naive(&x).unwrap();
            assert_eq!(planned, naive, "{kind:?} case {case}: planned != naive");
        }
    }
}

/// The property must also hold for the baseline backends (they exercise
/// the dense/tiled/CSR kernels and Winograd's copy-out path).
#[test]
fn prop_planned_matches_naive_all_backends() {
    for backend in [Backend::NaiveDense, Backend::OptDense, Backend::CsrSparse] {
        for (i, kind) in KINDS.iter().enumerate() {
            let engine = engine_for(*kind, backend, opts(6.0, 200 + i as u64), 2);
            let mut rng = Rng::new(0x6B00 + i as u64);
            let x = input_for(&engine, &mut rng);
            let planned = engine.run(&x).unwrap();
            let naive = engine.run_naive(&x).unwrap();
            assert_eq!(planned, naive, "{backend:?}/{kind:?}: planned != naive");
        }
    }
}

/// No two buffers with overlapping lifetimes may share arena bytes, on
/// any preset (the planner re-validates internally; this asserts it from
/// the public API against the shipped plans).
#[test]
fn no_live_intervals_overlap_in_arena() {
    for (i, kind) in KINDS.iter().enumerate() {
        let engine = engine_for(*kind, Backend::Grim, opts(8.0, 300 + i as u64), 1);
        let mem = &engine.plan().memory;
        mem.validate().unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert!(mem.arena_len > 0, "{kind:?}: empty arena");
        for b in &mem.buffers {
            assert!(b.first_use <= b.last_use, "{kind:?}: inverted interval");
        }
    }
}

/// Zero-alloc serving: each run performs exactly one arena checkout, and
/// sequential runs reuse one arena (no growth).
#[test]
fn runs_check_out_exactly_one_arena() {
    let engine = engine_for(ModelKind::MobilenetV2, Backend::Grim, opts(6.0, 41), 2);
    let pool = engine.workspace_pool();
    let mut rng = Rng::new(0x6C00);
    for _ in 0..7 {
        let x = input_for(&engine, &mut rng);
        engine.run(&x).unwrap();
    }
    let stats = pool.stats();
    assert_eq!(stats.checkouts, 7);
    assert_eq!(stats.arenas_created, 1);
}

/// A caller-managed workspace is also accepted (and size-checked).
#[test]
fn external_workspace_roundtrip() {
    let engine = engine_for(ModelKind::Gru, Backend::Grim, opts(4.0, 55), 1);
    let mut ws = Workspace::new(engine.plan().memory.arena_len);
    let mut rng = Rng::new(0x6D00);
    let x = input_for(&engine, &mut rng);
    let (a, _) = engine.run_planned(&x, &mut ws).unwrap();
    let b = engine.run(&x).unwrap();
    assert_eq!(a, b);

    let mut wrong = Workspace::new(engine.plan().memory.arena_len + 1);
    assert!(engine.run_planned(&x, &mut wrong).is_err(), "size mismatch must be rejected");
}

/// Multi-consumer view elision: a Flatten whose producer also feeds a
/// second consumer still aliases the producer's buffer (PR 2 only
/// aliased single-consumer views), and outputs stay bit-identical.
#[test]
fn multi_consumer_flatten_aliases_producer() {
    let module = grim::graph::dsl::parse(
        r#"
model "fanout"
in = Input(shape=[4,8,8])
c1 = Conv2D(in, out_c=4, kh=3, kw=3, stride=1, pad=1)
p1 = MaxPool2(c1)
f1 = Flatten(p1)
f2 = Flatten(p1)
fc1 = FC(f1, out_f=8)
fc2 = FC(f2, out_f=8)
out = Add(fc1, fc2)
"#,
    )
    .unwrap();
    let mut rng = Rng::new(0x7A11);
    let mut weights = grim::compiler::weights::WeightStore::new();
    let w1 = Tensor::rand_uniform(&[4, 36], 0.5, &mut rng);
    weights.insert("c1".into(), grim::compiler::weights::LayerWeights::dense(w1));
    for name in ["fc1", "fc2"] {
        let w = Tensor::rand_uniform(&[8, 64], 0.5, &mut rng);
        weights.insert(name.into(), grim::compiler::weights::LayerWeights::dense(w));
    }
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    // p1 (id 2) fans out to both flattens (ids 3, 4): both must alias
    // p1's buffer — same arena range, no extra allocation.
    let p1 = plan.memory.value_range(2).expect("pool output planned");
    assert_eq!(plan.memory.value_range(3), Some(p1), "f1 must alias p1");
    assert_eq!(plan.memory.value_range(4), Some(p1), "f2 must alias p1");
    let engine = Engine::new(plan, 1);
    let x = Tensor::rand_uniform(&[4, 8, 8], 1.0, &mut rng);
    let planned = engine.run(&x).unwrap();
    let naive = engine.run_naive(&x).unwrap();
    assert_eq!(planned, naive, "aliased views must not change results");
}

/// In-place ReLU elision: a standalone ReLU that survived epilogue
/// fusion (non-GEMM producer) and is its producer's final reader runs
/// over the producer's buffer — no copy, no extra allocation — and the
/// planned output stays bit-identical to the naive interpreter.
#[test]
fn final_reader_relu_aliases_producer() {
    let module = grim::graph::dsl::parse(
        r#"
model "inplace-relu"
in = Input(shape=[4,8,8])
c1 = Conv2D(in, out_c=4, kh=3, kw=3, stride=1, pad=1)
p1 = MaxPool2(c1)
r1 = ReLU(p1)
f1 = Flatten(r1)
out = FC(f1, out_f=8)
"#,
    )
    .unwrap();
    let mut rng = Rng::new(0x7B22);
    let mut weights = grim::compiler::weights::WeightStore::new();
    let w1 = Tensor::rand_uniform(&[4, 36], 0.5, &mut rng);
    weights.insert("c1".into(), grim::compiler::weights::LayerWeights::dense(w1));
    let w2 = Tensor::rand_uniform(&[8, 64], 0.5, &mut rng);
    weights.insert("out".into(), grim::compiler::weights::LayerWeights::dense(w2));
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    // r1 (id 3) is p1's (id 2) only reader: the activation overwrites
    // the pool output in place, and the downstream Flatten (id 4)
    // aliases the same bytes in turn.
    let p1 = plan.memory.value_range(2).expect("pool output planned");
    assert_eq!(plan.memory.value_range(3), Some(p1), "r1 must alias p1");
    assert_eq!(plan.memory.value_range(4), Some(p1), "f1 must alias r1");
    let engine = Engine::new(plan, 1);
    let x = Tensor::rand_uniform(&[4, 8, 8], 1.0, &mut rng);
    assert_eq!(
        engine.run(&x).unwrap(),
        engine.run_naive(&x).unwrap(),
        "in-place ReLU must not change results"
    );
}

/// The elision must NOT fire when the producer has a later reader: a
/// ReLU overwriting a branch point would corrupt the other branch.
#[test]
fn fanout_relu_keeps_its_own_buffer() {
    let module = grim::graph::dsl::parse(
        r#"
model "fanout-relu"
in = Input(shape=[4,8,8])
c1 = Conv2D(in, out_c=4, kh=3, kw=3, stride=1, pad=1)
p1 = MaxPool2(c1)
r1 = ReLU(p1)
f1 = Flatten(p1)
f2 = Flatten(r1)
fc1 = FC(f1, out_f=8)
fc2 = FC(f2, out_f=8)
out = Add(fc1, fc2)
"#,
    )
    .unwrap();
    let mut rng = Rng::new(0x7C33);
    let mut weights = grim::compiler::weights::WeightStore::new();
    let w1 = Tensor::rand_uniform(&[4, 36], 0.5, &mut rng);
    weights.insert("c1".into(), grim::compiler::weights::LayerWeights::dense(w1));
    for name in ["fc1", "fc2"] {
        let w = Tensor::rand_uniform(&[8, 64], 0.5, &mut rng);
        weights.insert(name.into(), grim::compiler::weights::LayerWeights::dense(w));
    }
    let plan = compile(&module, &weights, CompileOptions::default()).unwrap();
    // p1 (id 2) is also read by the Flatten at id 4, *after* the ReLU at
    // id 3 — so r1 must get its own buffer and keep the copy.
    let p1 = plan.memory.value_range(2).expect("pool output planned");
    assert_ne!(plan.memory.value_range(3), Some(p1), "fan-out ReLU must not alias p1");
    let engine = Engine::new(plan, 1);
    let x = Tensor::rand_uniform(&[4, 8, 8], 1.0, &mut rng);
    assert_eq!(
        engine.run(&x).unwrap(),
        engine.run_naive(&x).unwrap(),
        "copied ReLU must match naive"
    );
}

/// Dirty arenas must not leak between runs: run once, poison the arena,
/// run again — outputs identical.
#[test]
fn reused_arena_state_cannot_leak() {
    let engine = engine_for(ModelKind::Resnet18, Backend::Grim, opts(6.0, 77), 2);
    let mut ws = Workspace::new(engine.plan().memory.arena_len);
    let mut rng = Rng::new(0x6E00);
    let x = input_for(&engine, &mut rng);
    let (first, _) = engine.run_planned(&x, &mut ws).unwrap();
    // poison every byte of the arena
    let len = ws.arena_len();
    ws.slice_mut(0, len).fill(f32::NAN);
    let (second, _) = engine.run_planned(&x, &mut ws).unwrap();
    assert_eq!(first, second, "stale arena contents leaked into a later run");
}
