//! Observability integration tests: histogram-vs-exact-percentile
//! property, Prometheus round-trips, trace ring semantics under
//! wraparound and concurrency, Chrome export well-formedness, and the
//! end-to-end two-model server trace.
//!
//! Tracing state (`enable`/`disable`, the span rings, the batch-sampling
//! counter) is process-global, so every test that touches it serializes
//! on [`trace_lock`]. The rings are append-only across tests; assertions
//! therefore tolerate pre-existing spans and look for *their own*
//! markers (distinct interned model names per test) instead of assuming
//! an empty world.

use grim::compiler::passes::{compile, CompileOptions};
use grim::coordinator::{Server, ServerConfig};
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::obs::trace::{self, SpanKind};
use grim::obs::{fold_histograms, parse_text, Histogram, Registry};
use grim::serving::ModelRegistry;
use grim::tensor::Tensor;
use grim::util::stats::percentile;
use grim::util::Rng;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Serializes tests that flip the process-global tracing state.
fn trace_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn gru_plan(seed: u64) -> grim::compiler::ExecutionPlan {
    let opts = InitOptions { rate: 4.0, block: [4, 16], seed };
    let m = build_model(ModelKind::Gru, Preset::TimitMini, opts);
    let w = random_weights(&m, opts);
    compile(&m, &w, CompileOptions::default()).unwrap()
}

// ---------------------------------------------------------------------------
// Histogram vs exact percentiles
// ---------------------------------------------------------------------------

/// Property: over random sample populations, the histogram's quantile
/// estimate lands in the same log₂ bucket as the exact sort-based
/// percentile, count/min/max are exact, and the estimates are monotonic
/// in q.
#[test]
fn histogram_quantiles_match_exact_percentile_buckets() {
    let mut rng = Rng::new(0xB0B);
    for trial in 0..50 {
        let n = 1 + rng.index(400);
        let h = Histogram::new();
        let mut xs: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            // Mix of magnitudes: sub-µs to tens of ms in µs units.
            let v = rng.below(10u64.pow(1 + rng.index(5) as u32));
            h.record(v);
            xs.push(v as f64);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(h.count(), n as u64, "trial {trial}");
        assert_eq!(h.min(), xs[0] as u64, "trial {trial}");
        assert_eq!(h.max(), xs[n - 1] as u64, "trial {trial}");
        for q in [0.5, 0.9, 0.99] {
            let exact = percentile(&xs, q);
            let est = h.quantile(q);
            assert_eq!(
                Histogram::bucket_index(est.round() as u64),
                Histogram::bucket_index(exact.round() as u64),
                "trial {trial}: q={q} estimate {est} must land in the \
                 same bucket as exact {exact}"
            );
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9) && h.quantile(0.9) <= h.quantile(0.99));
    }
}

#[test]
fn prometheus_text_round_trip_preserves_quantiles() {
    let r = Registry::new();
    let h = r.histogram("grim_rt_us", &[("model", "m0")]);
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        h.record(rng.below(100_000));
    }
    r.counter("grim_rt_total", &[("model", "m0")]).add(500);
    let text = r.render();
    let samples = parse_text(&text).expect("render output must parse");
    let hists = fold_histograms(&samples);
    assert_eq!(hists.len(), 1);
    let ph = &hists[0];
    assert_eq!(ph.count, 500.0);
    assert_eq!(ph.sum, h.sum() as f64);
    // The parsed-side estimate only knows bucket upper bounds, so it can
    // sit one bucket above the live estimate when the live max clamps —
    // assert within one bucket.
    for q in [0.5, 0.9, 0.99] {
        let live = Histogram::bucket_index(h.quantile(q).round() as u64) as i64;
        let parsed = Histogram::bucket_index(ph.quantile(q).round() as u64) as i64;
        assert!(
            (live - parsed).abs() <= 1,
            "q={q}: parsed bucket {parsed} vs live bucket {live}"
        );
    }
}

// ---------------------------------------------------------------------------
// Trace rings
// ---------------------------------------------------------------------------

/// Overflowing a ring keeps the newest spans and drops the oldest.
#[test]
fn ring_wraparound_keeps_newest_spans() {
    let _g = trace_lock();
    trace::enable(1);
    let model = trace::intern("obs-test-wrap");
    let t0 = Instant::now();
    let total = trace::RING_CAP as u64 + 512;
    for i in 0..total {
        trace::record_span(SpanKind::Step, t0, t0 + Duration::from_micros(1), 1, model, i);
    }
    trace::disable();
    let ours: Vec<u64> = trace::snapshot()
        .into_iter()
        .filter(|s| s.model == model)
        .map(|s| s.a)
        .collect();
    assert!(ours.len() <= trace::RING_CAP, "ring is bounded");
    assert!(ours.contains(&(total - 1)), "newest span survives");
    assert!(!ours.contains(&0), "oldest span was overwritten");
}

/// Concurrent writers on their own rings + a racing reader: no torn
/// spans surface (every decoded span carries a payload one of the
/// writers actually wrote).
#[test]
fn concurrent_writers_and_reader_yield_only_committed_spans() {
    let _g = trace_lock();
    trace::enable(1);
    let model = trace::intern("obs-test-conc");
    let writers: Vec<_> = (0..4u64)
        .map(|w| {
            std::thread::spawn(move || {
                let t0 = Instant::now();
                for i in 0..20_000u64 {
                    // Payload encodes the writer, so a torn read that
                    // mixed two writers' slots would be detectable.
                    trace::record_span(
                        SpanKind::Worker,
                        t0,
                        t0 + Duration::from_micros(1),
                        w as u32,
                        model,
                        w * 1_000_000 + i,
                    );
                }
            })
        })
        .collect();
    for _ in 0..50 {
        for s in trace::snapshot().into_iter().filter(|s| s.model == model) {
            assert_eq!(
                s.a / 1_000_000,
                s.detail as u64,
                "span payload and writer id must come from one write"
            );
        }
    }
    for w in writers {
        w.join().unwrap();
    }
    trace::disable();
    let seen: std::collections::BTreeSet<u64> = trace::snapshot()
        .into_iter()
        .filter(|s| s.model == model)
        .map(|s| s.a / 1_000_000)
        .collect();
    assert_eq!(seen.len(), 4, "every writer thread's ring is visible");
}

#[test]
fn batch_sampling_is_one_in_n() {
    let _g = trace_lock();
    trace::enable(3);
    let sampled: Vec<bool> =
        (0..9).map(|_| trace::on_batch_start().sampled()).collect();
    trace::disable();
    assert_eq!(sampled.iter().filter(|s| **s).count(), 3, "one batch in three is sampled");
}

/// The per-batch sampling decision travels with each batch's guard:
/// while a sampled batch is in flight, runtime span sites stay active
/// even when an unsampled batch starts concurrently on another lane
/// (the old process-global flag let the later batch clobber the
/// earlier decision), and with only unsampled batches in flight they
/// are inactive.
#[test]
fn concurrent_batch_guards_do_not_clobber_sampling() {
    let _g = trace_lock();
    trace::enable(2); // sample every other batch
    // The global batch sequence carries over from other tests, so which
    // of two consecutive draws is the sampled one is not fixed — but
    // with period 2 it is exactly one of them.
    let a = trace::on_batch_start();
    let b = trace::on_batch_start();
    assert_ne!(a.sampled(), b.sampled(), "period 2 → one of two consecutive batches sampled");
    let (sampled, unsampled) = if a.sampled() { (a, b) } else { (b, a) };
    assert!(trace::active(), "a concurrent unsampled batch must not disable recording");
    drop(sampled);
    assert!(!trace::active(), "only an unsampled batch left in flight");
    drop(unsampled);
    assert!(trace::active(), "standalone (no batch in flight) is always sampled");
    trace::disable();
}

/// With tracing off, engine runs record nothing and the guard is a
/// single relaxed load (`active()` short-circuits on ENABLED). Skipped
/// under the `GRIM_TRACE=1` CI leg, where tracing is intentionally on.
#[test]
fn tracing_off_records_no_spans() {
    if std::env::var("GRIM_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        return;
    }
    let _g = trace_lock();
    trace::disable();
    assert!(!trace::active());
    assert!(trace::begin().is_none(), "no clock read on the off path");
    let engine = Engine::new(gru_plan(40), 2);
    let mut rng = Rng::new(2);
    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
    let before = trace::snapshot().len();
    for _ in 0..3 {
        engine.run(&x).unwrap();
    }
    assert_eq!(trace::snapshot().len(), before, "tracing-off runs must record nothing");
}

// ---------------------------------------------------------------------------
// Chrome export
// ---------------------------------------------------------------------------

#[test]
fn chrome_export_is_well_formed() {
    let _g = trace_lock();
    trace::enable(1);
    let model = trace::intern("obs-test-export");
    let t0 = Instant::now();
    let t1 = t0 + Duration::from_micros(250);
    for kind in [
        SpanKind::Queue,
        SpanKind::BatchForm,
        SpanKind::Dispatch,
        SpanKind::Run,
        SpanKind::Step,
        SpanKind::Worker,
        SpanKind::Respond,
    ] {
        trace::record_span(kind, t0, t1, 3, model, 9);
    }
    trace::disable();
    let json = trace::export_chrome();
    let summary = trace::validate_chrome(&json).expect("export must validate");
    assert!(summary.events >= 7);
    assert!(summary.models.contains("obs-test-export"));
    for name in ["queue-wait", "batch-form", "dispatch", "run", "chunk", "respond"] {
        assert!(summary.names.contains(name), "missing span name {name}");
    }
    assert!(summary.cats.contains("request") && summary.cats.contains("kernel"));
}

#[test]
fn counter_spans_export_as_chrome_counter_events() {
    let _g = trace_lock();
    trace::enable(1);
    let model = trace::intern("obs-test-counter");
    trace::record_counter(trace::CTR_INFLIGHT, model, 2);
    trace::record_counter(trace::CTR_PENDING_ADMISSIONS, model, 1);
    trace::record_counter(trace::CTR_ARENA_BYTES, model, 4096);
    trace::disable();
    let json = trace::export_chrome();
    let summary = trace::validate_chrome(&json).expect("counter export must validate");
    assert!(summary.counters >= 3, "expected >= 3 counter samples, saw {}", summary.counters);
    for name in ["inflight_batches", "pending_admissions", "arena_bytes"] {
        assert!(summary.names.contains(name), "missing counter track {name}");
    }
}

// ---------------------------------------------------------------------------
// Task-scoped busy attribution under concurrent dispatch
// ---------------------------------------------------------------------------

/// Regression (PR 9): pool busy time is credited to the CALLING thread's
/// task counter at each barrier, never to other threads'. The old scheme
/// derived per-step busy time from deltas of the process-global counter,
/// so two engines dispatching concurrently cross-contaminated each
/// other's per-layer metrics.
#[test]
fn task_busy_attribution_is_caller_scoped() {
    use grim::util::threadpool::ThreadPool;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    grim::obs::set_pool_timing(true);
    let stop = Arc::new(AtomicBool::new(false));
    let s2 = Arc::clone(&stop);
    // A "foreign" dispatcher thread hammering its own pool: its chunk
    // time must be credited to ITS task counter, not ours.
    let noise = std::thread::spawn(move || {
        let pool = ThreadPool::new(2);
        let before = grim::obs::task_busy_nanos();
        while !s2.load(Ordering::Relaxed) {
            pool.run_partitioned(4096, |_w, lo, hi| {
                let mut acc = 0.0f32;
                for i in lo..hi {
                    acc += (i as f32).sqrt();
                }
                std::hint::black_box(acc);
            });
        }
        grim::obs::task_busy_nanos() - before
    });
    // Wait until the noise thread's pool work is demonstrably timed.
    let pool_busy0 = grim::obs::pool_busy_nanos();
    let deadline = Instant::now() + Duration::from_secs(10);
    while grim::obs::pool_busy_nanos() < pool_busy0 + 200_000 {
        assert!(Instant::now() < deadline, "noise thread never accumulated busy time");
        std::thread::yield_now();
    }
    // This thread issued no pool work: its task counter must not move
    // while the noise thread keeps dispatching.
    let mine0 = grim::obs::task_busy_nanos();
    std::thread::sleep(Duration::from_millis(20));
    assert_eq!(
        grim::obs::task_busy_nanos(),
        mine0,
        "another thread's pool work leaked into this thread's task counter"
    );
    // Work issued from THIS thread is credited here, and the engine's
    // per-step busy metrics (derived from the same counter) sum to
    // exactly the delta we observe around the run.
    let mut engine = Engine::new(gru_plan(44), 2);
    engine.collect_metrics = true;
    let mut rng = Rng::new(5);
    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
    let before = grim::obs::task_busy_nanos();
    let (_, m) = engine.run_with_metrics(&x).unwrap();
    let delta_us = (grim::obs::task_busy_nanos() - before) as f64 / 1e3;
    assert!(
        (delta_us - m.total_busy_micros()).abs() < 0.5,
        "task-counter delta {delta_us} µs vs per-step busy sum {} µs",
        m.total_busy_micros()
    );
    stop.store(true, Ordering::Relaxed);
    let noise_credited = noise.join().unwrap();
    assert!(noise_credited > 0, "the noise thread's barriers credit its own task counter");
}

// ---------------------------------------------------------------------------
// End-to-end: two models behind one traced server
// ---------------------------------------------------------------------------

/// The acceptance path: a multi-model server driven with tracing on
/// yields a valid Chrome trace containing queue/batch/dispatch/kernel
/// spans for both models, and the metrics dump reports per-model
/// latency quantiles.
#[test]
fn two_model_server_trace_and_metrics() {
    let _g = trace_lock();
    trace::enable(1);
    let registry = std::sync::Arc::new(ModelRegistry::new(2));
    registry.insert_plan("obs-rnn-a", gru_plan(41));
    registry.insert_plan("obs-rnn-b", gru_plan(42));
    let server = Server::start_registry(std::sync::Arc::clone(&registry), ServerConfig::default());
    let mut rng = Rng::new(3);
    for i in 0..8 {
        let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
        let name = if i % 2 == 0 { "obs-rnn-a" } else { "obs-rnn-b" };
        let resp = server.infer_on(name, x).unwrap();
        assert!(resp.queue_ms >= 0.0 && resp.batch_ms >= 0.0 && resp.exec_ms > 0.0);
    }
    let prom = server.render_prometheus();
    let stats = server.shutdown();
    trace::disable();

    // Per-model latency summaries cover both models.
    let names: Vec<&str> = stats.per_model.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names.contains(&"obs-rnn-a") && names.contains(&"obs-rnn-b"), "{names:?}");
    for (name, s) in &stats.per_model {
        assert_eq!(s.count, 4, "model {name}");
        assert!(s.p99 >= s.p50 && s.p50 > 0.0, "model {name}");
    }
    assert!(stats.batch_size.count >= 8, "one batch-size sample per batch");

    // The Prometheus dump parses and carries per-model series (labeled
    // latency histograms + per-kernel-kind step times + registry gauges).
    let samples = parse_text(&prom).expect("stats dump must parse");
    for model in ["obs-rnn-a", "obs-rnn-b"] {
        assert!(
            samples.iter().any(|s| s.name == "grim_request_latency_us_count"
                && s.label("model") == Some(model)
                && s.value == 4.0),
            "missing latency family for {model}"
        );
        assert!(
            samples
                .iter()
                .any(|s| s.name == "grim_step_time_us_count"
                    && s.label("model") == Some(model)
                    && s.label("kind") == Some("gru")),
            "missing gru step-time family for {model}"
        );
        assert!(
            samples.iter().any(|s| s.name == "grim_model_resident_bytes"
                && s.label("model") == Some(model)
                && s.value > 0.0),
            "missing registry gauge for {model}"
        );
        assert!(
            samples
                .iter()
                .any(|s| s.name == "grim_roofline_pct" && s.label("model") == Some(model)),
            "missing roofline gauge for {model}"
        );
    }

    // The trace holds request- and kernel-level spans for both models.
    let json = trace::export_chrome();
    let summary = trace::validate_chrome(&json).expect("server trace must validate");
    assert!(summary.models.contains("obs-rnn-a") && summary.models.contains("obs-rnn-b"));
    for name in ["queue-wait", "batch-form", "dispatch", "run", "gru", "respond"] {
        assert!(summary.names.contains(name), "missing span {name} in {:?}", summary.names);
    }
    assert!(summary.counters > 0, "sampled batches must emit counter tracks");
    assert!(summary.names.contains("inflight_batches"), "{:?}", summary.names);
}

/// Served engines collect per-layer metrics; the wall vs busy split and
/// weight-bytes annotations are populated for parallel GEMM steps.
#[test]
fn run_metrics_carry_busy_time_and_weight_bytes() {
    let mut engine = Engine::new(gru_plan(43), 2);
    engine.collect_metrics = true;
    let mut rng = Rng::new(4);
    let x = Tensor::rand_uniform(&[20, 19], 1.0, &mut rng);
    let (_, m) = engine.run_with_metrics(&x).unwrap();
    assert!(!m.layers.is_empty());
    assert!(m.total_weight_bytes() > 0, "GRU gates must report weight bytes");
    assert!(m.total_busy_micros() >= 0.0);
    let gru = m.layers.iter().find(|l| l.kind == "gru").expect("gru step present");
    assert!(gru.weight_bytes > 0);
}
