//! Quantized (`--dtype i8`) serving acceptance tests:
//!
//! * on all four model presets, the i8 plan tracks the f32 plan's
//!   post-softmax outputs within a fixed budget while shrinking the
//!   packed weight bytes;
//! * a quantized plan round-trips through the v5 `.grimc` grammar
//!   bit-identically (codes, row sums recomputed at load, scale);
//! * pre-v5 grammars still write/load f32 plans, and **refuse** to
//!   write a quantized plan (no silent i8 drop on downgrade).

use grim::artifact;
use grim::compiler::passes::{compile, CompileOptions};
use grim::compiler::plan::ExecutionPlan;
use grim::engine::Engine;
use grim::models::{build_model, random_weights, InitOptions, ModelKind, Preset};
use grim::quant::DType;
use grim::tensor::Tensor;
use grim::util::Rng;

const KINDS: [ModelKind; 4] =
    [ModelKind::Vgg16, ModelKind::Resnet18, ModelKind::MobilenetV2, ModelKind::Gru];

fn compiled(kind: ModelKind, seed: u64, dtype: DType) -> ExecutionPlan {
    let o = InitOptions { rate: 6.0, block: [4, 16], seed };
    let m = build_model(kind, Preset::CifarMini, o);
    let w = random_weights(&m, o);
    compile(&m, &w, CompileOptions { dtype, ..Default::default() }).unwrap()
}

fn input_for(engine: &Engine, rng: &mut Rng) -> Tensor {
    let dims = engine.plan().memory.shapes[engine.plan().input_id].clone();
    Tensor::rand_uniform(&dims, 1.0, rng)
}

/// Every preset's i8 plan stays within the serving error budget of its
/// f32 twin on post-softmax outputs, quantizes at least one layer, and
/// carries strictly fewer packed bytes. (The tight per-layer analytic
/// bound lives in the bcrc_gemm unit test; this is the end-to-end
/// budget across stacked quantized layers.)
#[test]
fn i8_tracks_f32_on_all_presets() {
    if grim::compiler::packing::force_unpacked() {
        return; // nothing packed to quantize under GRIM_FORCE_UNPACKED
    }
    for (i, kind) in KINDS.iter().enumerate() {
        let f32_plan = compiled(*kind, 900 + i as u64, DType::F32);
        let q_plan = compiled(*kind, 900 + i as u64, DType::I8);
        assert!(q_plan.packing.i8_layers > 0, "{kind:?}: no layer quantized");
        assert!(
            q_plan.packing.packed_bytes < f32_plan.packing.packed_bytes,
            "{kind:?}: i8 must shrink packed bytes ({} vs {})",
            q_plan.packing.packed_bytes,
            f32_plan.packing.packed_bytes
        );
        let [(_, fq_f32), (_, fq_i8)] = q_plan.weight_bytes_by_dtype();
        assert!(fq_i8 > 0, "{kind:?}: dtype split must report i8 bytes");
        let [(_, ff_f32), (_, ff_i8)] = f32_plan.weight_bytes_by_dtype();
        assert_eq!(ff_i8, 0, "{kind:?}: f32 plan must report no i8 bytes");
        assert!(fq_f32 + fq_i8 < ff_f32, "{kind:?}: total weight bytes must shrink");
        let ef = Engine::new(f32_plan, 2);
        let eq = Engine::new(q_plan, 2);
        let mut rng = Rng::new(0x9100 + i as u64);
        for case in 0..2 {
            let x = input_for(&ef, &mut rng);
            let a = ef.run(&x).unwrap();
            let b = eq.run(&x).unwrap();
            assert!(
                a.allclose(&b, 1e-1, 1e-1),
                "{kind:?} case {case}: i8 drifted from f32 by {}",
                a.max_abs_diff(&b)
            );
        }
    }
}

/// A quantized plan survives the v5 byte round-trip bit-identically:
/// same i8 layer count, same packed bytes, same outputs.
#[test]
fn v5_round_trip_preserves_quantized_plans() {
    if grim::compiler::packing::force_unpacked() {
        return;
    }
    for (i, kind) in [ModelKind::Vgg16, ModelKind::Gru].iter().enumerate() {
        let plan = compiled(*kind, 910 + i as u64, DType::I8);
        let bytes = artifact::to_bytes(&plan).unwrap();
        assert_eq!(
            u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
            artifact::GRIMC_VERSION,
            "quantized artifacts write the current version"
        );
        let loaded = artifact::from_bytes(&bytes).unwrap();
        assert_eq!(loaded.packing.i8_layers, plan.packing.i8_layers, "{kind:?}");
        assert_eq!(loaded.packing.packed_bytes, plan.packing.packed_bytes, "{kind:?}");
        assert_eq!(loaded.describe(), plan.describe(), "{kind:?}");
        assert_eq!(loaded.weight_bytes_by_dtype(), plan.weight_bytes_by_dtype(), "{kind:?}");
        let mem = Engine::new(plan, 2);
        let aot = Engine::new(loaded, 2);
        let mut rng = Rng::new(0x9200 + i as u64);
        for case in 0..2 {
            let x = input_for(&mem, &mut rng);
            assert_eq!(
                mem.run(&x).unwrap(),
                aot.run(&x).unwrap(),
                "{kind:?} case {case}: loaded i8 plan must run bit-identically"
            );
        }
    }
}

/// f32 plans still write at every historical version (v1–v4) and load
/// bit-identically; quantized plans refuse every pre-v5 version with a
/// clear error instead of silently dropping their codes.
#[test]
fn pre_v5_versions_load_f32_and_reject_i8() {
    let plan = compiled(ModelKind::Gru, 920, DType::F32);
    let mut rng = Rng::new(0x9300);
    let mem = Engine::new(plan.clone(), 2);
    let x = input_for(&mem, &mut rng);
    let want = mem.run(&x).unwrap();
    for v in 1..=4u32 {
        let bytes = artifact::to_bytes_versioned(&plan, v)
            .unwrap_or_else(|e| panic!("f32 plan must encode at v{v}: {e}"));
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), v);
        let loaded = artifact::from_bytes(&bytes).unwrap_or_else(|e| panic!("load v{v}: {e}"));
        assert_eq!(loaded.packing.i8_layers, 0, "pre-v5 artifacts are all-f32");
        let aot = Engine::new(loaded, 2);
        assert_eq!(want, aot.run(&x).unwrap(), "v{v} artifact must run bit-identically");
    }
    if grim::compiler::packing::force_unpacked() {
        return;
    }
    let q_plan = compiled(ModelKind::Gru, 920, DType::I8);
    for v in 1..=4u32 {
        let err = artifact::to_bytes_versioned(&q_plan, v)
            .expect_err("quantized plans must refuse pre-v5 versions");
        assert!(
            err.to_string().contains("version >= 5"),
            "v{v}: unexpected error {err}"
        );
    }
    assert!(artifact::to_bytes_versioned(&q_plan, 5).is_ok());
}
