//! Vendored, dependency-free subset of the `anyhow` crate.
//!
//! The build environment is hermetic (no crates.io access), so the crate
//! graph must be self-contained. grim uses only a small slice of anyhow's
//! API — `Result`, `Error`, and the `anyhow!` / `bail!` / `ensure!`
//! macros — which this shim reproduces with compatible semantics:
//!
//! * `Error` is an opaque, `Send + Sync` message wrapper with `Display`
//!   and `Debug`;
//! * any `std::error::Error` converts into it via `?` (the blanket `From`
//!   below — sound because `Error` itself deliberately does *not*
//!   implement `std::error::Error`, exactly like real anyhow);
//! * the macros accept `format!`-style arguments.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error carrying a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` on io::Error, Utf8Error, ParseFloatError, ... — anything that is a
// std error. No conflict with `impl From<T> for T` because Error is not
// itself a std::error::Error.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Render the source chain inline so context is not lost.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow!("fmt", args...)` — construct an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!("fmt", args...)` — early-return an `Err`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt", args...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("bad {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad 42");
        assert_eq!(format!("{e:?}"), "bad 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("nope").is_err());
    }
}
